//! The design-space axes swept in the paper's §IV: Edge TPU (Table II) and
//! FuseMax (Table III) points, unified behind one `DesignPoint` type —
//! plus the cluster-scale deployment space ([`ClusterSpace`]): device
//! counts × link tiers × DP/PP/TP factorizations, the searchable
//! dimension behind the Fig 5 edge→datacenter Pareto front. The
//! heterogeneous variant ([`ClusterSpace::enumerate_hetero`]) adds the
//! **stage-placement** dimension: which device class of a mixed pool
//! hosts which pipeline stage.

use crate::hardware::accelerator::Accelerator;
use crate::hardware::presets::{EdgeTpuParams, FuseMaxParams};
use crate::parallelism::{Cluster, HeteroCluster, HeteroPoint, LinkTier, Strategy};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DesignPoint {
    EdgeTpu(EdgeTpuParams),
    FuseMax(FuseMaxParams),
}

impl DesignPoint {
    pub fn build(&self) -> Accelerator {
        match self {
            DesignPoint::EdgeTpu(p) => p.build(),
            DesignPoint::FuseMax(p) => p.build(),
        }
    }

    /// Total compute resource (x-axis of Fig 8).
    pub fn total_macs(&self) -> u64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.total_macs(),
            DesignPoint::FuseMax(p) => p.total_macs(),
        }
    }

    /// Per-PE compute resource U·L (colour axis of Fig 8) or the buffer
    /// bandwidth (colour axis of Fig 9).
    pub fn color_axis(&self) -> f64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.per_pe_macs() as f64,
            DesignPoint::FuseMax(p) => p.buffer_bw as f64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            DesignPoint::EdgeTpu(p) => format!(
                "edge,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.u, p.l, p.local_mem, p.regfile
            ),
            DesignPoint::FuseMax(p) => format!(
                "fusemax,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.vector_pes, p.buffer_bw, p.buffer_size, p.offchip_bw
            ),
        }
    }

    pub fn edge_space(stride: usize) -> Vec<DesignPoint> {
        EdgeTpuParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::EdgeTpu)
            .collect()
    }

    pub fn fusemax_space(stride: usize) -> Vec<DesignPoint> {
        FuseMaxParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::FuseMax)
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Cluster-scale deployment space (paper §II-C1 / Fig 5 made searchable)
// ---------------------------------------------------------------------------

/// One deployment point: a device count on a fabric tier running one
/// hybrid DP/PP/TP factorization (`dp · pp · tp == devices`). The pure
/// strategies are the degenerate factorizations — `(n,1,1)` is data
/// parallelism, `(1,n,1)` pipeline, `(1,1,n)` tensor parallelism — so
/// enumerating hybrids covers everything (see the `parallelism`
/// degeneracy contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterPoint {
    pub devices: usize,
    pub tier: LinkTier,
    pub dp: usize,
    pub pp: usize,
    /// Pipeline microbatches (1 whenever `pp == 1`).
    pub microbatches: usize,
    pub tp: usize,
}

impl ClusterPoint {
    pub fn strategy(&self) -> Strategy {
        Strategy::Hybrid {
            dp: self.dp,
            pp_stages: self.pp,
            microbatches: self.microbatches,
            tp: self.tp,
        }
    }

    pub fn cluster(&self) -> Cluster {
        self.tier.cluster(self.devices)
    }

    /// Stable row label, e.g. `edge,n4,dp2,pp2,m4,tp1`.
    pub fn label(&self) -> String {
        format!(
            "{},n{},dp{},pp{},m{},tp{}",
            self.tier.as_str(),
            self.devices,
            self.dp,
            self.pp,
            self.microbatches,
            self.tp
        )
    }
}

/// The enumerable cluster space: device counts × link tiers ×
/// factorizations (× microbatch options for pipelined points).
#[derive(Debug, Clone)]
pub struct ClusterSpace {
    pub device_counts: Vec<usize>,
    pub tiers: Vec<LinkTier>,
    /// Microbatch counts tried for every factorization with `pp > 1`.
    pub microbatches: Vec<usize>,
}

impl ClusterSpace {
    /// Powers of two from 1 to `max_devices`, all three link tiers,
    /// microbatch options {4, 8}.
    pub fn default_space(max_devices: usize) -> Self {
        let mut device_counts = vec![];
        let mut d = 1usize;
        while d <= max_devices.max(1) {
            device_counts.push(d);
            d *= 2;
        }
        ClusterSpace {
            device_counts,
            tiers: LinkTier::all().to_vec(),
            microbatches: vec![4, 8],
        }
    }

    /// All ordered triples `(dp, pp, tp)` with `dp·pp·tp == n`.
    pub fn factorizations(n: usize) -> Vec<(usize, usize, usize)> {
        let n = n.max(1);
        let mut out = vec![];
        for dp in 1..=n {
            if n % dp != 0 {
                continue;
            }
            let rest = n / dp;
            for pp in 1..=rest {
                if rest % pp != 0 {
                    continue;
                }
                out.push((dp, pp, rest / pp));
            }
        }
        out
    }

    /// Pipelines up to this deep get their stage placements enumerated
    /// exhaustively; deeper ones fall back to contiguous class blocks
    /// (ascending and descending class order) — the sequence count at
    /// depth `pp` over `k` classes is `k^pp`-bounded and would swamp the
    /// sweep beyond this.
    pub const MAX_EXHAUSTIVE_PLACEMENT: usize = 8;

    /// Enumerate every heterogeneous deployment point of a device pool:
    /// factorizations `dp·pp·tp ≤ total devices` × stage placements
    /// feasible under the per-class device counts (each stage occupies
    /// `dp·tp` devices of its class) × microbatch options. `m = 1` (no
    /// microbatching) is always tried for pipelined points — it is the
    /// minimum-energy pipeline corner (no per-microbatch weight
    /// re-streaming). Symmetry pruning: [`HeteroCluster::new`] merges
    /// identically-named pool entries, so no two enumerated placements
    /// are permutations of indistinguishable classes; the `seen` set
    /// drops exact duplicates (e.g. repeated `m = 1`). Deterministic
    /// order: devices, factorization, placement (lexicographic class
    /// order), microbatches.
    pub fn enumerate_hetero(hc: &HeteroCluster, microbatches: &[usize]) -> Vec<HeteroPoint> {
        let total = hc.total_devices();
        let mut out: Vec<HeteroPoint> = vec![];
        let mut seen: std::collections::HashSet<HeteroPoint> = std::collections::HashSet::new();
        for n in 1..=total {
            for (dp, pp, tp) in Self::factorizations(n) {
                let gang = dp * tp;
                let caps: Vec<usize> = hc.counts.iter().map(|&c| c / gang).collect();
                if caps.iter().sum::<usize>() < pp {
                    continue; // not enough stage slots anywhere
                }
                let placements = if pp <= Self::MAX_EXHAUSTIVE_PLACEMENT {
                    class_sequences(pp, &caps)
                } else {
                    class_block_sequences(pp, &caps)
                };
                for placement in placements {
                    let mut ms: Vec<usize> = vec![1];
                    if pp > 1 {
                        ms.extend(microbatches.iter().copied());
                    }
                    for &m in &ms {
                        let p = HeteroPoint {
                            dp,
                            pp,
                            microbatches: m,
                            tp,
                            placement: placement.clone(),
                        };
                        debug_assert!(p.feasible(hc));
                        if seen.insert(p.clone()) {
                            out.push(p);
                        }
                    }
                }
            }
        }
        out
    }

    /// The block-fallback restriction of
    /// [`enumerate_hetero`](ClusterSpace::enumerate_hetero): every
    /// factorization gets only the contiguous class-block placements
    /// (what deep pipelines degrade to beyond
    /// [`MAX_EXHAUSTIVE_PLACEMENT`](ClusterSpace::MAX_EXHAUSTIVE_PLACEMENT)),
    /// at every depth. This scales linearly in pool size where full
    /// enumeration is `k^pp`-bounded — it is the evaluable backbone (and
    /// the head-to-head baseline) of the `ga-cluster` search on 256+
    /// device pools. Same loop structure, ordering and dedup as the full
    /// enumeration, so its points are a subset of
    /// [`enumerate_hetero`](ClusterSpace::enumerate_hetero) wherever
    /// that is computable.
    pub fn enumerate_hetero_fallback(
        hc: &HeteroCluster,
        microbatches: &[usize],
    ) -> Vec<HeteroPoint> {
        let total = hc.total_devices();
        let mut out: Vec<HeteroPoint> = vec![];
        let mut seen: std::collections::HashSet<HeteroPoint> = std::collections::HashSet::new();
        for n in 1..=total {
            for (dp, pp, tp) in Self::factorizations(n) {
                let gang = dp * tp;
                let caps: Vec<usize> = hc.counts.iter().map(|&c| c / gang).collect();
                if caps.iter().sum::<usize>() < pp {
                    continue;
                }
                for placement in class_block_sequences(pp, &caps) {
                    let mut ms: Vec<usize> = vec![1];
                    if pp > 1 {
                        ms.extend(microbatches.iter().copied());
                    }
                    for &m in &ms {
                        let p = HeteroPoint {
                            dp,
                            pp,
                            microbatches: m,
                            tp,
                            placement: placement.clone(),
                        };
                        debug_assert!(p.feasible(hc));
                        if seen.insert(p.clone()) {
                            out.push(p);
                        }
                    }
                }
            }
        }
        out
    }

    /// Exact size of [`enumerate_hetero`](ClusterSpace::enumerate_hetero)
    /// without materializing it — the denominator of the `ga-cluster`
    /// points-evaluated ratio, computable even where the enumeration
    /// itself would take hours. Placement counts come from a multinomial
    /// DP (distinct cap-bounded class sequences) below the exhaustive
    /// wall and from the ≤ 2 contiguous blocks above it; the microbatch
    /// menu is deduplicated exactly as the enumeration's `seen` set
    /// would (no other duplicate source exists: a factorization pins its
    /// device count, and placements within one generator are distinct).
    pub fn count_hetero(hc: &HeteroCluster, microbatches: &[usize]) -> u64 {
        let total = hc.total_devices();
        let mut count = 0u64;
        for n in 1..=total {
            for (dp, pp, tp) in Self::factorizations(n) {
                let gang = dp * tp;
                let caps: Vec<usize> = hc.counts.iter().map(|&c| c / gang).collect();
                if caps.iter().sum::<usize>() < pp {
                    continue;
                }
                let placements = if pp <= Self::MAX_EXHAUSTIVE_PLACEMENT {
                    count_class_sequences(pp, &caps)
                } else {
                    class_block_sequences(pp, &caps).len() as u64
                };
                let ms = if pp > 1 {
                    let mut ms: Vec<usize> = vec![1];
                    for &m in microbatches {
                        if !ms.contains(&m) {
                            ms.push(m);
                        }
                    }
                    ms.len() as u64
                } else {
                    1
                };
                count = count.saturating_add(placements.saturating_mul(ms));
            }
        }
        count
    }

    /// A [`crate::ga::DeploymentGenome`] carries the same information as
    /// a [`HeteroPoint`]; the GA evolves the former, the cost model
    /// consumes the latter.
    pub fn genome_to_hetero(g: &crate::ga::DeploymentGenome) -> HeteroPoint {
        HeteroPoint {
            dp: g.dp,
            pp: g.pp,
            microbatches: g.microbatches,
            tp: g.tp,
            placement: g.placement.clone(),
        }
    }

    /// Inverse of [`genome_to_hetero`](ClusterSpace::genome_to_hetero)
    /// (used to warm-start the GA from enumerated fronts).
    pub fn hetero_to_genome(p: &HeteroPoint) -> crate::ga::DeploymentGenome {
        crate::ga::DeploymentGenome {
            dp: p.dp,
            pp: p.pp,
            microbatches: p.microbatches,
            tp: p.tp,
            placement: p.placement.clone(),
        }
    }

    /// Enumerate every deployment point of the space, deterministically
    /// ordered (devices, tier order, factorization, microbatches).
    pub fn enumerate(&self) -> Vec<ClusterPoint> {
        let mut out = vec![];
        for &devices in &self.device_counts {
            for &tier in &self.tiers {
                for (dp, pp, tp) in Self::factorizations(devices) {
                    if pp > 1 {
                        for &m in &self.microbatches {
                            out.push(ClusterPoint { devices, tier, dp, pp, microbatches: m, tp });
                        }
                    } else {
                        out.push(ClusterPoint { devices, tier, dp, pp, microbatches: 1, tp });
                    }
                }
            }
        }
        out
    }
}

/// All class-index sequences of length `len` under per-class multiplicity
/// caps, in lexicographic class order.
fn class_sequences(len: usize, caps: &[usize]) -> Vec<Vec<usize>> {
    fn rec(len: usize, cur: &mut Vec<usize>, left: &mut [usize], out: &mut Vec<Vec<usize>>) {
        if cur.len() == len {
            out.push(cur.clone());
            return;
        }
        for c in 0..left.len() {
            if left[c] == 0 {
                continue;
            }
            left[c] -= 1;
            cur.push(c);
            rec(len, cur, left, out);
            cur.pop();
            left[c] += 1;
        }
    }
    let mut out = vec![];
    let mut left = caps.to_vec();
    rec(len, &mut Vec::with_capacity(len), &mut left, &mut out);
    out
}

/// Number of distinct class-index sequences of length `len` under
/// per-class multiplicity caps — `class_sequences(len, caps).len()`
/// without materializing. DP over classes: admitting a class with cap
/// `c` maps `dp[j] → Σ_{u≤min(c,j)} dp[j-u]·C(j,u)` (choose the new
/// class's positions among the `j` slots).
fn count_class_sequences(len: usize, caps: &[usize]) -> u64 {
    let mut dp = vec![0u64; len + 1];
    dp[0] = 1;
    for &c in caps {
        let mut next = vec![0u64; len + 1];
        for j in 0..=len {
            for u in 0..=c.min(j) {
                next[j] = next[j].saturating_add(
                    dp[j - u].saturating_mul(binom(j as u64, u as u64)),
                );
            }
        }
        dp = next;
    }
    dp[len]
}

/// Binomial coefficient C(n, k) for the small values the placement DP
/// needs (`n ≤ MAX_EXHAUSTIVE_PLACEMENT`).
fn binom(n: u64, k: u64) -> u64 {
    let k = k.min(n - k);
    let mut r = 1u64;
    for i in 0..k {
        r = r * (n - i) / (i + 1);
    }
    r
}

/// Contiguous class-block placements (each class's stages adjacent), in
/// ascending and descending class order — the fallback beyond
/// [`ClusterSpace::MAX_EXHAUSTIVE_PLACEMENT`].
fn class_block_sequences(len: usize, caps: &[usize]) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![];
    for rev in [false, true] {
        let order: Vec<usize> = if rev {
            (0..caps.len()).rev().collect()
        } else {
            (0..caps.len()).collect()
        };
        let mut seq = Vec::with_capacity(len);
        for &c in &order {
            for _ in 0..caps[c] {
                if seq.len() < len {
                    seq.push(c);
                }
            }
        }
        if seq.len() == len && !out.contains(&seq) {
            out.push(seq);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spaces_build() {
        let e = DesignPoint::edge_space(500);
        let f = DesignPoint::fusemax_space(200);
        assert!(!e.is_empty() && !f.is_empty());
        for p in e.iter().chain(&f) {
            let a = p.build();
            assert!(a.total_macs() > 0);
            // the built HDA adds auxiliary vector cores, so its MAC count
            // is at least the point's headline U·L·nPEs resource
            assert!(a.total_macs() >= p.total_macs());
        }
    }

    #[test]
    fn labels_unique_within_space() {
        let pts = DesignPoint::edge_space(100);
        let labels: std::collections::HashSet<String> =
            pts.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), pts.len());
    }

    #[test]
    fn factorizations_cover_and_multiply_back() {
        for n in [1usize, 2, 4, 6, 8, 16] {
            let fs = ClusterSpace::factorizations(n);
            assert!(!fs.is_empty());
            for &(dp, pp, tp) in &fs {
                assert_eq!(dp * pp * tp, n);
            }
            // the three pure strategies are always present
            assert!(fs.contains(&(n, 1, 1)));
            assert!(fs.contains(&(1, n, 1)));
            assert!(fs.contains(&(1, 1, n)));
            // no duplicates
            let set: std::collections::HashSet<_> = fs.iter().collect();
            assert_eq!(set.len(), fs.len());
        }
        assert_eq!(ClusterSpace::factorizations(4).len(), 6);
    }

    #[test]
    fn hetero_enumeration_is_feasible_unique_and_covers_the_extremes() {
        use crate::parallelism::DeviceClass;

        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let pts = ClusterSpace::enumerate_hetero(&hc, &[2, 4]);
        assert!(!pts.is_empty());
        let set: std::collections::HashSet<&HeteroPoint> = pts.iter().collect();
        assert_eq!(set.len(), pts.len(), "duplicate deployment points");
        let labels: std::collections::HashSet<String> = pts.iter().map(|p| p.label(&hc)).collect();
        assert_eq!(labels.len(), pts.len(), "labels must be unique");
        for p in &pts {
            assert!(p.feasible(&hc), "infeasible point enumerated: {p:?}");
            assert!(p.devices() <= hc.total_devices());
            assert!(p.pp > 1 || p.microbatches == 1);
        }
        // the uniform extremes and genuinely mixed placements all appear
        assert!(pts.iter().any(|p| !p.is_mixed() && p.placement == vec![0]));
        assert!(pts.iter().any(|p| !p.is_mixed() && p.placement == vec![1]));
        assert!(pts.iter().any(|p| p.is_mixed()));
        // m = 1 is always tried for pipelined points
        assert!(pts.iter().any(|p| p.pp > 1 && p.microbatches == 1));
        // symmetry pruning: a split pool of identical classes enumerates
        // exactly the same points as the merged pool
        let split = HeteroCluster::new(vec![(DeviceClass::edge(), 2), (DeviceClass::edge(), 2)]);
        let merged = HeteroCluster::new(vec![(DeviceClass::edge(), 4)]);
        assert_eq!(
            ClusterSpace::enumerate_hetero(&split, &[2]),
            ClusterSpace::enumerate_hetero(&merged, &[2])
        );
    }

    #[test]
    fn factorizations_are_duplicate_free_deterministic_and_cover_n() {
        use crate::util::proptest::{check, UsizeIn};
        check(60, &UsizeIn(1, 96), |&n| {
            let fs = ClusterSpace::factorizations(n);
            let set: std::collections::HashSet<_> = fs.iter().collect();
            set.len() == fs.len()
                && fs == ClusterSpace::factorizations(n)
                && fs.iter().all(|&(dp, pp, tp)| dp * pp * tp == n)
                // deterministic order: strictly lexicographic in (dp, pp)
                && fs
                    .windows(2)
                    .all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1))
        });
    }

    #[test]
    fn hetero_enumeration_is_duplicate_free_and_deterministically_ordered() {
        use crate::parallelism::DeviceClass;
        use crate::util::proptest::{check, UsizeIn};
        check(6, &UsizeIn(1, 5), |&edge_n| {
            let hc = HeteroCluster::new(vec![
                (DeviceClass::edge(), edge_n),
                (DeviceClass::datacenter(), 6 - edge_n),
            ]);
            let pts = ClusterSpace::enumerate_hetero(&hc, &[2, 4]);
            let set: std::collections::HashSet<&HeteroPoint> = pts.iter().collect();
            set.len() == pts.len()
                && pts == ClusterSpace::enumerate_hetero(&hc, &[2, 4])
                // outer loop ascends through total device counts
                && pts.windows(2).all(|w| w[0].devices() <= w[1].devices())
        });
    }

    /// Two-class placements are contiguous blocks iff the class indices
    /// are monotone (all of one class adjacent, then the other).
    fn is_monotone(p: &[usize]) -> bool {
        p.windows(2).all(|w| w[0] <= w[1]) || p.windows(2).all(|w| w[0] >= w[1])
    }

    #[test]
    fn block_fallback_engages_exactly_beyond_max_exhaustive_placement() {
        use crate::parallelism::DeviceClass;

        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 6),
            (DeviceClass::datacenter(), 6),
        ]);
        let pts = ClusterSpace::enumerate_hetero(&hc, &[2]);
        let max = ClusterSpace::MAX_EXHAUSTIVE_PLACEMENT;

        // at the boundary depth the enumeration is still exhaustive: the
        // dp=tp=1, m=1 placements are exactly `class_sequences`, and some
        // of them interleave classes (not a contiguous block)
        let at_max: Vec<Vec<usize>> = pts
            .iter()
            .filter(|p| p.pp == max && p.dp == 1 && p.tp == 1 && p.microbatches == 1)
            .map(|p| p.placement.clone())
            .collect();
        assert_eq!(at_max, class_sequences(max, &[6, 6]));
        assert!(at_max.iter().any(|p| !is_monotone(p)));

        // beyond the boundary every placement degrades to a contiguous
        // class block, at most two (ascending/descending) per factorization
        let mut per_fact: std::collections::HashMap<
            (usize, usize, usize),
            std::collections::HashSet<Vec<usize>>,
        > = std::collections::HashMap::new();
        for p in pts.iter().filter(|p| p.pp > max) {
            assert!(is_monotone(&p.placement), "non-block deep placement: {p:?}");
            per_fact
                .entry((p.dp, p.pp, p.tp))
                .or_default()
                .insert(p.placement.clone());
        }
        assert!(!per_fact.is_empty(), "pool admits no pipelines deeper than {max}");
        for set in per_fact.values() {
            assert!(set.len() <= 2);
        }
    }

    #[test]
    fn count_hetero_matches_the_materialized_enumeration() {
        use crate::parallelism::DeviceClass;
        use crate::util::proptest::{check, UsizeIn};
        check(6, &UsizeIn(1, 6), |&edge_n| {
            let hc = HeteroCluster::new(vec![
                (DeviceClass::edge(), edge_n),
                (DeviceClass::datacenter(), 7 - edge_n),
            ]);
            ClusterSpace::count_hetero(&hc, &[2, 4])
                == ClusterSpace::enumerate_hetero(&hc, &[2, 4]).len() as u64
                // duplicate menu entries must not inflate the count
                && ClusterSpace::count_hetero(&hc, &[1, 2, 2])
                    == ClusterSpace::enumerate_hetero(&hc, &[1, 2, 2]).len() as u64
        });
        // single-class pool too (no placement choice at all)
        let uni = HeteroCluster::new(vec![(DeviceClass::server(), 9)]);
        assert_eq!(
            ClusterSpace::count_hetero(&uni, &[4]),
            ClusterSpace::enumerate_hetero(&uni, &[4]).len() as u64
        );
    }

    #[test]
    fn fallback_enumeration_is_a_block_only_subset_of_the_full_one() {
        use crate::parallelism::DeviceClass;
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 6),
            (DeviceClass::datacenter(), 6),
        ]);
        let full = ClusterSpace::enumerate_hetero(&hc, &[2]);
        let fallback = ClusterSpace::enumerate_hetero_fallback(&hc, &[2]);
        assert!(!fallback.is_empty());
        let set: std::collections::HashSet<&HeteroPoint> = fallback.iter().collect();
        assert_eq!(set.len(), fallback.len(), "duplicate fallback points");
        assert!(fallback.len() < full.len());
        // subset of the full enumeration, and every placement is a block
        let full_set: std::collections::HashSet<&HeteroPoint> = full.iter().collect();
        for p in &fallback {
            assert!(full_set.contains(p), "fallback point not in full enumeration: {p:?}");
            assert!(is_monotone(&p.placement), "non-block fallback placement: {p:?}");
        }
        // beyond the exhaustive wall the two enumerations coincide exactly
        let max = ClusterSpace::MAX_EXHAUSTIVE_PLACEMENT;
        let deep_full: Vec<&HeteroPoint> = full.iter().filter(|p| p.pp > max).collect();
        let deep_fb: Vec<&HeteroPoint> = fallback.iter().filter(|p| p.pp > max).collect();
        assert_eq!(deep_full, deep_fb);
    }

    #[test]
    fn genome_point_mapping_round_trips() {
        let p = HeteroPoint { dp: 2, pp: 3, microbatches: 4, tp: 1, placement: vec![0, 1, 1] };
        let g = ClusterSpace::hetero_to_genome(&p);
        assert_eq!(g.dp, 2);
        assert_eq!(g.pp, 3);
        assert_eq!(g.microbatches, 4);
        assert_eq!(g.tp, 1);
        assert_eq!(g.placement, vec![0, 1, 1]);
        assert_eq!(ClusterSpace::genome_to_hetero(&g), p);
    }

    #[test]
    fn sequence_count_dp_matches_the_recursive_generator() {
        for (len, caps) in [
            (2usize, vec![2usize, 1]),
            (4, vec![2, 2]),
            (4, vec![1, 1]),
            (3, vec![3, 3, 3]),
            (8, vec![6, 6]),
            (5, vec![0, 5, 2]),
        ] {
            assert_eq!(
                count_class_sequences(len, &caps),
                class_sequences(len, &caps).len() as u64,
                "len={len} caps={caps:?}"
            );
        }
        assert_eq!(binom(8, 0), 1);
        assert_eq!(binom(8, 3), 56);
        assert_eq!(binom(8, 8), 1);
    }

    #[test]
    fn class_sequences_respect_caps() {
        let seqs = class_sequences(2, &[2, 1]);
        assert_eq!(seqs, vec![vec![0, 0], vec![0, 1], vec![1, 0]]);
        assert!(class_sequences(4, &[1, 1]).is_empty());
        // the deep-pipeline fallback keeps only contiguous class blocks
        let blocks = class_block_sequences(4, &[2, 2]);
        assert_eq!(blocks, vec![vec![0, 0, 1, 1], vec![1, 1, 0, 0]]);
    }

    #[test]
    fn cluster_space_enumerates_unique_labelled_points() {
        let space = ClusterSpace::default_space(8);
        assert_eq!(space.device_counts, vec![1, 2, 4, 8]);
        let pts = space.enumerate();
        assert!(!pts.is_empty());
        let labels: std::collections::HashSet<String> =
            pts.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), pts.len(), "labels must be unique");
        for p in &pts {
            assert_eq!(p.dp * p.pp * p.tp, p.devices);
            assert!(p.pp > 1 || p.microbatches == 1);
            assert_eq!(p.cluster().devices, p.devices);
        }
        // every tier appears at every device count
        for &d in &space.device_counts {
            for &t in &space.tiers {
                assert!(pts.iter().any(|p| p.devices == d && p.tier == t));
            }
        }
    }
}
