//! The design-space axes swept in the paper's §IV: Edge TPU (Table II) and
//! FuseMax (Table III) points, unified behind one `DesignPoint` type.

use crate::hardware::accelerator::Accelerator;
use crate::hardware::presets::{EdgeTpuParams, FuseMaxParams};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DesignPoint {
    EdgeTpu(EdgeTpuParams),
    FuseMax(FuseMaxParams),
}

impl DesignPoint {
    pub fn build(&self) -> Accelerator {
        match self {
            DesignPoint::EdgeTpu(p) => p.build(),
            DesignPoint::FuseMax(p) => p.build(),
        }
    }

    /// Total compute resource (x-axis of Fig 8).
    pub fn total_macs(&self) -> u64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.total_macs(),
            DesignPoint::FuseMax(p) => p.total_macs(),
        }
    }

    /// Per-PE compute resource U·L (colour axis of Fig 8) or the buffer
    /// bandwidth (colour axis of Fig 9).
    pub fn color_axis(&self) -> f64 {
        match self {
            DesignPoint::EdgeTpu(p) => p.per_pe_macs() as f64,
            DesignPoint::FuseMax(p) => p.buffer_bw as f64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            DesignPoint::EdgeTpu(p) => format!(
                "edge,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.u, p.l, p.local_mem, p.regfile
            ),
            DesignPoint::FuseMax(p) => format!(
                "fusemax,{},{},{},{},{},{}",
                p.x_pes, p.y_pes, p.vector_pes, p.buffer_bw, p.buffer_size, p.offchip_bw
            ),
        }
    }

    pub fn edge_space(stride: usize) -> Vec<DesignPoint> {
        EdgeTpuParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::EdgeTpu)
            .collect()
    }

    pub fn fusemax_space(stride: usize) -> Vec<DesignPoint> {
        FuseMaxParams::space_strided(stride)
            .into_iter()
            .map(DesignPoint::FuseMax)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spaces_build() {
        let e = DesignPoint::edge_space(500);
        let f = DesignPoint::fusemax_space(200);
        assert!(!e.is_empty() && !f.is_empty());
        for p in e.iter().chain(&f) {
            let a = p.build();
            assert!(a.total_macs() > 0);
            // the built HDA adds auxiliary vector cores, so its MAC count
            // is at least the point's headline U·L·nPEs resource
            assert!(a.total_macs() >= p.total_macs());
        }
    }

    #[test]
    fn labels_unique_within_space() {
        let pts = DesignPoint::edge_space(100);
        let labels: std::collections::HashSet<String> =
            pts.iter().map(|p| p.label()).collect();
        assert_eq!(labels.len(), pts.len());
    }
}
