//! DSE pre-filter descriptors: flatten an accelerator and a workload graph
//! into the dense rows the AOT Pallas cost kernel consumes (DESIGN.md S13).
//! Layout must match python/compile/kernels/ref.py.

use crate::hardware::accelerator::Accelerator;
use crate::hardware::energy;
use crate::runtime::cost_kernel::{CfgRow, CostKernel, CostOut, LayRow};
use crate::workload::graph::Graph;
use crate::workload::op::LoopDim;

/// Accelerator → config descriptor row.
pub fn accel_to_cfg(accel: &Accelerator) -> CfgRow {
    CfgRow {
        macs: accel.total_macs() as f32,
        onchip_bw: accel.cores.iter().map(|c| c.onchip_bw).sum::<f64>() as f32,
        offchip_bw: accel.offchip_bw as f32,
        local_mem: accel.total_local_mem() as f32,
        e_mac: energy::E_MAC_PJ as f32,
        e_onchip: energy::E_LOCAL_PJ_PER_BYTE as f32,
        e_offchip: energy::E_DRAM_PJ_PER_BYTE as f32,
    }
}

/// Workload graph → layer descriptor rows (one per node).
pub fn graph_to_layers(g: &Graph) -> Vec<LayRow> {
    (0..g.len())
        .map(|n| {
            let kind = &g.node(n).kind;
            let in_bytes: u64 = g.in_edges(n).map(|e| e.bytes).sum();
            let weight_bytes = kind.weight_elems() * g.elem_bytes;
            let out_bytes = kind.out_elems() * g.elem_bytes;
            // independent output elements = exploitable MAC-level parallelism
            let dims = kind.loop_dims();
            let par: usize = dims
                .iter()
                .filter(|(d, _)| {
                    matches!(d, LoopDim::B | LoopDim::K | LoopDim::Ox | LoopDim::Oy | LoopDim::M | LoopDim::E)
                })
                .map(|(_, s)| *s)
                .product();
            LayRow {
                flops: 2.0 * kind.macs() as f32,
                onchip_bytes: (in_bytes + weight_bytes + out_bytes) as f32,
                offchip_bytes: (in_bytes + weight_bytes + out_bytes) as f32,
                parallelism: par.max(1) as f32,
                working_set: (weight_bytes + out_bytes) as f32,
                weight_bytes: weight_bytes as f32,
            }
        })
        .collect()
}

/// Score accelerators against a graph, preferring the AOT kernel and
/// falling back to the native twin when no runtime is available.
pub fn prefilter_scores(
    kernel: Option<&CostKernel>,
    accels: &[Accelerator],
    g: &Graph,
) -> Vec<CostOut> {
    let cfgs: Vec<CfgRow> = accels.iter().map(accel_to_cfg).collect();
    let layers = graph_to_layers(g);
    match kernel {
        Some(k) => k
            .eval(&cfgs, &layers)
            .expect("cost kernel execution failed"),
        None => crate::runtime::cost_kernel::cost_eval_native(&cfgs, &layers),
    }
}

/// Keep the indices of the best `keep_frac` configs by roofline cycles
/// (ties broken by energy). Never returns fewer than `min_keep`.
pub fn select_survivors(scores: &[CostOut], keep_frac: f64, min_keep: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // total_cmp: one NaN roofline score from a degenerate config must not
    // abort the whole search in stage 1 (NaNs sort last, i.e. pruned
    // first)
    idx.sort_by(|&a, &b| {
        scores[a]
            .cycles
            .total_cmp(&scores[b].cycles)
            .then(scores[a].energy_pj.total_cmp(&scores[b].energy_pj))
    });
    let keep = ((scores.len() as f64 * keep_frac).ceil() as usize)
        .max(min_keep)
        .min(scores.len());
    idx.truncate(keep);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::EdgeTpuParams;
    use crate::workload::models::resnet18;

    #[test]
    fn descriptors_are_finite_and_positive() {
        let g = resnet18(1, 32, 10);
        let layers = graph_to_layers(&g);
        assert_eq!(layers.len(), g.len());
        for l in &layers {
            assert!(l.flops >= 0.0 && l.parallelism >= 1.0);
            assert!(l.onchip_bytes.is_finite());
        }
        let cfg = accel_to_cfg(&EdgeTpuParams::baseline().build());
        assert!(cfg.macs > 0.0 && cfg.offchip_bw > 0.0);
    }

    #[test]
    fn native_prefilter_ranks_bigger_faster() {
        let g = resnet18(1, 32, 10);
        let small = EdgeTpuParams { u: 16, l: 1, ..EdgeTpuParams::baseline() }.build();
        let big = EdgeTpuParams { u: 128, l: 8, ..EdgeTpuParams::baseline() }.build();
        let scores = prefilter_scores(None, &[small, big], &g);
        assert!(scores[1].cycles < scores[0].cycles);
    }

    #[test]
    fn survivor_selection() {
        let g = resnet18(1, 32, 10);
        let accels: Vec<_> = EdgeTpuParams::space_strided(500)
            .into_iter()
            .map(|p| p.build())
            .collect();
        let scores = prefilter_scores(None, &accels, &g);
        let surv = select_survivors(&scores, 0.25, 1);
        assert_eq!(surv.len(), (accels.len() as f64 * 0.25).ceil() as usize);
        // survivors are the fastest quartile
        let worst_kept = surv.iter().map(|&i| scores[i].cycles).fold(0.0, f32::max);
        let dropped_best = (0..accels.len())
            .filter(|i| !surv.contains(i))
            .map(|i| scores[i].cycles)
            .fold(f32::INFINITY, f32::min);
        assert!(worst_kept <= dropped_best);
    }
}
