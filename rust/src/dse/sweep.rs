//! The DSE sweep orchestrator (DESIGN.md S11): the L3 coordination layer.
//!
//! A sweep walks a list of design points; for each, it builds the HDA,
//! schedules the inference and/or training graph with the configured
//! fusion strategy, and emits one row per (point, mode). Work is
//! distributed over a worker pool (std::thread — tokio is not vendored in
//! this offline environment, and the workload is pure CPU anyway) with a
//! shared job queue, and results are streamed back over a channel so the
//! caller can report progress (backpressure = bounded queue).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use super::space::DesignPoint;
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::mapping::MappingConfig;
use crate::scheduler::{schedule, Partition};
use crate::workload::graph::Graph;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Inference,
    Training,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Inference => "inference",
            Mode::Training => "training",
        }
    }
}

/// How the workload is partitioned for scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionStrategy {
    /// Layer-by-layer (the Fig 10 "Base").
    None,
    /// Greedy constrained fusion (fast; used inside sweeps).
    Greedy,
}

/// One sweep result row (a point in Figs 1/8/9).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub index: usize,
    pub label: String,
    pub mode: Mode,
    pub total_macs: u64,
    pub color_axis: f64,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub peak_dram_bytes: u64,
    pub utilization: f64,
}

#[derive(Clone)]
pub struct SweepConfig {
    pub mapping: MappingConfig,
    pub fusion: FusionStrategy,
    pub fusion_constraints: FusionConstraints,
    pub modes: Vec<Mode>,
    pub workers: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            mapping: MappingConfig::default(),
            fusion: FusionStrategy::Greedy,
            fusion_constraints: FusionConstraints::default(),
            modes: vec![Mode::Inference, Mode::Training],
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

/// Precomputed per-mode partitions: the fusion decision depends only on
/// the workload graph and the fusion constraints, NOT on the accelerator,
/// so the sweep computes it once and reuses it across every design point
/// (§Perf: this hoisting took the per-point cost from 1.06 ms to the cost
/// of two schedules).
pub struct SweepPartitions {
    pub fwd: Partition,
    pub train: Partition,
}

impl SweepPartitions {
    pub fn prepare(fwd: &Graph, train: &Graph, cfg: &SweepConfig) -> Self {
        let make = |g: &Graph| match cfg.fusion {
            FusionStrategy::None => Partition::singletons(g),
            FusionStrategy::Greedy => fuse_greedy(g, &cfg.fusion_constraints),
        };
        SweepPartitions { fwd: make(fwd), train: make(train) }
    }
}

/// Evaluate one design point (both modes). Public so benches can time the
/// per-point cost directly.
pub fn evaluate_point(
    index: usize,
    point: &DesignPoint,
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
) -> Vec<SweepRow> {
    let parts = SweepPartitions::prepare(fwd, train, cfg);
    evaluate_point_prepared(index, point, fwd, train, &parts, cfg)
}

/// Hot-path variant with precomputed partitions.
pub fn evaluate_point_prepared(
    index: usize,
    point: &DesignPoint,
    fwd: &Graph,
    train: &Graph,
    parts: &SweepPartitions,
    cfg: &SweepConfig,
) -> Vec<SweepRow> {
    let accel = point.build();
    cfg.modes
        .iter()
        .map(|&mode| {
            let (g, partition) = match mode {
                Mode::Inference => (fwd, &parts.fwd),
                Mode::Training => (train, &parts.train),
            };
            let r = schedule(g, partition, &accel, &cfg.mapping);
            SweepRow {
                index,
                label: point.label(),
                mode,
                total_macs: point.total_macs(),
                color_axis: point.color_axis(),
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                peak_dram_bytes: r.peak_dram_bytes,
                utilization: r.utilization(),
            }
        })
        .collect()
}

/// Run the sweep over a worker pool. Rows are returned sorted by
/// (index, mode) so output is deterministic regardless of thread timing.
pub fn run_sweep(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    mut progress: impl FnMut(usize, usize),
) -> Vec<SweepRow> {
    let n = points.len();
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = mpsc::channel::<Vec<SweepRow>>();
    // fusion is accelerator-independent: solve once, share across workers
    let parts = SweepPartitions::prepare(fwd, train, cfg);
    let parts = &parts;

    let workers = cfg.workers.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let cfg = cfg.clone();
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let rows =
                    evaluate_point_prepared(i, &points[i], fwd, train, parts, &cfg);
                if tx.send(rows).is_err() {
                    break;
                }
            });
        }
        drop(tx);

        let mut all: Vec<SweepRow> = Vec::with_capacity(n * cfg.modes.len());
        let mut done = 0usize;
        while let Ok(rows) = rx.recv() {
            all.extend(rows);
            done += 1;
            progress(done, n);
        }
        all.sort_by_key(|r| (r.index, r.mode != Mode::Inference));
        all
    })
}

/// Pareto front over (latency, energy): indices of non-dominated rows.
pub fn pareto_front(rows: &[SweepRow]) -> Vec<usize> {
    let mut front = vec![];
    'outer: for (i, r) in rows.iter().enumerate() {
        for (j, o) in rows.iter().enumerate() {
            if i != j
                && o.latency_cycles <= r.latency_cycles
                && o.energy_pj <= r.energy_pj
                && (o.latency_cycles < r.latency_cycles || o.energy_pj < r.energy_pj)
            {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::workload::models::resnet18;

    fn graphs() -> (Graph, Graph) {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        (fwd, tg.graph)
    }

    #[test]
    fn sweep_covers_all_points_and_modes() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(2000);
        let cfg = SweepConfig { workers: 2, ..Default::default() };
        let mut calls = 0;
        let rows = run_sweep(&points, &fwd, &train, &cfg, |_, _| calls += 1);
        assert_eq!(calls, points.len());
        assert_eq!(rows.len(), points.len() * 2);
        // deterministic ordering
        for (i, chunk) in rows.chunks(2).enumerate() {
            assert_eq!(chunk[0].index, i);
            assert_eq!(chunk[0].mode, Mode::Inference);
            assert_eq!(chunk[1].mode, Mode::Training);
        }
    }

    #[test]
    fn training_costs_more_than_inference() {
        let (fwd, train) = graphs();
        let points = vec![DesignPoint::edge_space(1)[0]];
        let rows = run_sweep(&points, &fwd, &train, &SweepConfig::default(), |_, _| {});
        let inf = &rows[0];
        let tr = &rows[1];
        assert!(tr.latency_cycles > inf.latency_cycles * 1.5);
        assert!(tr.energy_pj > inf.energy_pj * 1.5);
    }

    #[test]
    fn sweep_is_deterministic_across_worker_counts() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(3000);
        let one = run_sweep(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 1, ..Default::default() },
            |_, _| {},
        );
        let four = run_sweep(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 4, ..Default::default() },
            |_, _| {},
        );
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.energy_pj, b.energy_pj);
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(1000);
        let rows = run_sweep(&points, &fwd, &train, &SweepConfig::default(), |_, _| {});
        let inf_rows: Vec<SweepRow> =
            rows.iter().filter(|r| r.mode == Mode::Inference).cloned().collect();
        let front = pareto_front(&inf_rows);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                if i != j {
                    let (a, b) = (&inf_rows[i], &inf_rows[j]);
                    assert!(
                        !(a.latency_cycles <= b.latency_cycles
                            && a.energy_pj <= b.energy_pj
                            && (a.latency_cycles < b.latency_cycles
                                || a.energy_pj < b.energy_pj))
                    );
                }
            }
        }
    }
}
