//! The DSE sweep orchestrator (DESIGN.md S11): the L3 coordination layer.
//!
//! A sweep walks a list of design points; for each, it builds the HDA,
//! schedules the inference and/or training graph with the configured
//! fusion strategy, and emits one row per (point, mode). All
//! orchestration — the worker pool, the shared cost-cache lifecycle
//! (`use_cache`/`cache_dir`/`cache_cap`), progress reporting and the
//! deterministic result ordering — lives in the generic
//! [`super::engine`] harness; this module only defines the per-family
//! [`Evaluate`] instances ([`SweepEval`], [`ClusterEval`],
//! [`HeteroEval`]) and the thin entry points the figures/CLI call.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;

use super::engine::{
    Engine, EngineConfig, EngineError, Evaluate, HeteroSpace, Objectives, RunOutcome, SharedCache,
};
use super::space::{ClusterPoint, DesignPoint};
use crate::autodiff::TrainingGraph;
use crate::eval::{CacheStats, CostCache};
use crate::fusion::{fuse_greedy, FusionConstraints};
use crate::hardware::accelerator::Accelerator;
use crate::mapping::MappingConfig;
use crate::parallelism::{
    model_strategy_bound, model_strategy_hetero_bound, model_strategy_hetero_memo,
    model_strategy_memo, HeteroCluster, HeteroPoint, LinkTier, StageCutsMemo,
};
use crate::scheduler::{schedule_lower_bound, schedule_with_cache, Partition};
use crate::workload::graph::Graph;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Inference,
    Training,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Inference => "inference",
            Mode::Training => "training",
        }
    }
}

/// How the workload is partitioned for scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionStrategy {
    /// Layer-by-layer (the Fig 10 "Base").
    None,
    /// Greedy constrained fusion (fast; used inside sweeps).
    Greedy,
}

/// One sweep result row (a point in Figs 1/8/9).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub index: usize,
    pub label: String,
    pub mode: Mode,
    pub total_macs: u64,
    pub color_axis: f64,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub peak_dram_bytes: u64,
    pub utilization: f64,
}

impl SweepRow {
    /// The typed minimized objective set of this row (a single device).
    pub fn objectives(&self) -> Objectives {
        Objectives {
            latency_cycles: self.latency_cycles,
            energy_pj: self.energy_pj,
            memory_bytes: self.peak_dram_bytes,
            devices: 1,
        }
    }
}

#[derive(Clone)]
pub struct SweepConfig {
    pub mapping: MappingConfig,
    pub fusion: FusionStrategy,
    pub fusion_constraints: FusionConstraints,
    pub modes: Vec<Mode>,
    pub workers: usize,
    /// Share one `eval::CostCache` across the sweep's worker pool (§Perf).
    /// `false` (the `--no-cache` escape hatch) recomputes every group cost
    /// — results are bit-identical either way; this exists for A/B timing.
    /// When off, it also wins over `cache_dir`: nothing is loaded or
    /// saved.
    pub use_cache: bool,
    /// Persist the cost cache across process runs (`--cache-dir`): warm-
    /// load the snapshot in this directory before the sweep, write it back
    /// after. `None` (the default) keeps the cache in-memory only.
    /// Results are bit-identical either way — a stale or incompatible
    /// snapshot is rejected wholesale (see `eval::persist`).
    pub cache_dir: Option<PathBuf>,
    /// Bound the cache to ~this many entries with the sharded CLOCK policy
    /// (`--cache-cap`); 0 (the default) = unbounded.
    pub cache_cap: usize,
    /// Journal every completed point to this directory (`--run-dir`),
    /// making the sweep resumable after a crash; `None` (the default)
    /// journals nothing. See `dse::journal`.
    pub run_dir: Option<PathBuf>,
    /// Replay a `run_dir` journal left by a killed run (`--resume`):
    /// completed points are restored bit-identically, only the remainder
    /// evaluates.
    pub resume: bool,
    /// Use a caller-owned resident cache (`monet serve`'s warm cache)
    /// instead of opening one per run; the owner controls snapshot
    /// persistence. See [`SharedCache`]. Ignored when `use_cache` is
    /// off.
    pub shared_cache: Option<SharedCache>,
    /// Bound-based front pruning (ROADMAP item 5): skip design points
    /// whose admissible lower bound ([`Evaluate::lower_bound`]) is
    /// Pareto-dominated by an already-evaluated row. `false` (the
    /// library default) enumerates the whole space — the figure and CSV
    /// entry points want every row; the CLI commands and the
    /// `monet serve` daemon default it **on** (`--no-prune` is the
    /// escape hatch) because only dominated rows are ever elided: the
    /// rank-0 Pareto front is bit-identical either way, pinned by
    /// `tests/front_equivalence.rs`.
    pub prune: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            mapping: MappingConfig::default(),
            fusion: FusionStrategy::Greedy,
            fusion_constraints: FusionConstraints::default(),
            modes: vec![Mode::Inference, Mode::Training],
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            use_cache: true,
            cache_dir: None,
            cache_cap: 0,
            run_dir: None,
            resume: false,
            shared_cache: None,
            prune: false,
        }
    }
}

impl SweepConfig {
    /// The engine-level orchestration knobs of this sweep config (worker
    /// count + the cost-cache lifecycle triple). Every sweep family and
    /// the staged search derive their [`EngineConfig`] through this one
    /// method, so the CLI cache flags cannot drift across commands.
    pub fn engine(&self) -> EngineConfig {
        EngineConfig {
            workers: self.workers,
            use_cache: self.use_cache,
            cache_dir: self.cache_dir.clone(),
            cache_cap: self.cache_cap,
            run_dir: self.run_dir.clone(),
            resume: self.resume,
            shared_cache: self.shared_cache.clone(),
            prune: self.prune,
        }
    }
}

/// Precomputed per-mode partitions: the fusion decision depends only on
/// the workload graph and the fusion constraints, NOT on the accelerator,
/// so the sweep computes it once and reuses it across every design point
/// (§Perf: this hoisting took the per-point cost from 1.06 ms to the cost
/// of two schedules).
pub struct SweepPartitions {
    pub fwd: Partition,
    pub train: Partition,
}

impl SweepPartitions {
    pub fn prepare(fwd: &Graph, train: &Graph, cfg: &SweepConfig) -> Self {
        let make = |g: &Graph| match cfg.fusion {
            FusionStrategy::None => Partition::singletons(g),
            FusionStrategy::Greedy => fuse_greedy(g, &cfg.fusion_constraints),
        };
        SweepPartitions { fwd: make(fwd), train: make(train) }
    }
}

/// Evaluate one design point (both modes). Public so benches can time the
/// per-point cost directly.
pub fn evaluate_point(
    index: usize,
    point: &DesignPoint,
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
) -> Vec<SweepRow> {
    let parts = SweepPartitions::prepare(fwd, train, cfg);
    evaluate_point_prepared(index, point, fwd, train, &parts, cfg)
}

/// Hot-path variant with precomputed partitions.
pub fn evaluate_point_prepared(
    index: usize,
    point: &DesignPoint,
    fwd: &Graph,
    train: &Graph,
    parts: &SweepPartitions,
    cfg: &SweepConfig,
) -> Vec<SweepRow> {
    evaluate_point_cached(index, point, fwd, train, parts, cfg, None)
}

/// Hottest-path variant: precomputed partitions plus a shared group-cost
/// memo. `run_sweep`/`search` pass one `CostCache` for the whole batch, so
/// design points sharing core classes (and the many repeated layer shapes
/// inside one graph) compute each unique group cost once.
pub fn evaluate_point_cached(
    index: usize,
    point: &DesignPoint,
    fwd: &Graph,
    train: &Graph,
    parts: &SweepPartitions,
    cfg: &SweepConfig,
    cache: Option<&CostCache>,
) -> Vec<SweepRow> {
    let accel = point.build();
    cfg.modes
        .iter()
        .map(|&mode| {
            let (g, partition) = match mode {
                Mode::Inference => (fwd, &parts.fwd),
                Mode::Training => (train, &parts.train),
            };
            let r = schedule_with_cache(g, partition, &accel, &cfg.mapping, cache);
            SweepRow {
                index,
                label: point.label(),
                mode,
                total_macs: point.total_macs(),
                color_axis: point.color_axis(),
                latency_cycles: r.latency_cycles,
                energy_pj: r.energy_pj,
                peak_dram_bytes: r.peak_dram_bytes,
                utilization: r.utilization(),
            }
        })
        .collect()
}

/// The single-device accelerator sweep as an [`Evaluate`] instance: one
/// [`SweepRow`] per configured mode, via [`evaluate_point_cached`]. The
/// fusion partitions are accelerator-independent, solved once and shared
/// read-only across the pool. Stateless per worker (the shared cost
/// cache is the only memo this family needs).
pub struct SweepEval<'a> {
    pub fwd: &'a Graph,
    pub train: &'a Graph,
    pub parts: &'a SweepPartitions,
    pub cfg: &'a SweepConfig,
}

/// One-hot mode prefix of the sweep family's pruning geometry
/// (`[inference, training]`). Rows of different modes must never
/// dominate each other — the per-mode Pareto fronts are independent —
/// and one-hot components make cross-mode vectors incomparable while
/// same-mode prefixes tie exactly.
fn mode_tag(mode: Mode) -> [f64; 2] {
    match mode {
        Mode::Inference => [1.0, 0.0],
        Mode::Training => [0.0, 1.0],
    }
}

// audit:pure
impl Evaluate for SweepEval<'_> {
    type Point = DesignPoint;
    type Row = SweepRow;
    type Scratch = ();

    fn scratch(&self) {}

    fn evaluate(
        &self,
        index: usize,
        point: &DesignPoint,
        cache: Option<&CostCache>,
        _scratch: &mut (),
    ) -> Vec<SweepRow> {
        evaluate_point_cached(index, point, self.fwd, self.train, self.parts, self.cfg, cache)
    }

    /// One admissible bound per configured mode, in the geometry
    /// `[mode one-hot ×2, latency_cycles, energy_pj]`: the MAC/bandwidth
    /// roofline of [`schedule_lower_bound`] never exceeds the scheduled
    /// latency or energy of any fusion/mapping choice (the admissibility
    /// proof lives on that function), and the one-hot prefix keeps
    /// dominance within a mode.
    fn lower_bound(
        &self,
        _index: usize,
        point: &DesignPoint,
        _scratch: &mut (),
    ) -> Option<Vec<Vec<f64>>> {
        let accel = point.build();
        Some(
            self.cfg
                .modes
                .iter()
                .map(|&mode| {
                    let g = match mode {
                        Mode::Inference => self.fwd,
                        Mode::Training => self.train,
                    };
                    let b = schedule_lower_bound(g, &accel, &self.cfg.mapping);
                    let [mi, mt] = mode_tag(mode);
                    vec![mi, mt, b.latency_cycles, b.energy_pj]
                })
                .collect(),
        )
    }

    fn row_objectives(&self, row: &SweepRow) -> Option<Vec<f64>> {
        let [mi, mt] = mode_tag(row.mode);
        Some(vec![mi, mt, row.latency_cycles, row.energy_pj])
    }
}

/// Run the sweep over the engine's worker pool. Rows are returned sorted
/// by (index, mode) so output is deterministic regardless of thread
/// timing.
pub fn run_sweep(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> Vec<SweepRow> {
    run_sweep_stats(points, fwd, train, cfg, progress).0
}

/// [`run_sweep`] plus the sweep-level cache counters (hits/misses/entries
/// of the one `CostCache` shared across the worker pool; zeros when
/// `cfg.use_cache` is off).
pub fn run_sweep_stats(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> (Vec<SweepRow>, CacheStats) {
    unwrap_outcome("sweep", run_sweep_outcome(points, fwd, train, cfg, progress))
}

/// The full-fidelity sweep entry point: [`run_sweep_stats`] with the
/// crash-safety layer (`cfg.run_dir`/`cfg.resume`) and structured
/// degradation — isolated per-point failures come back as data in
/// [`RunOutcome::failures`] instead of aborting the sweep, and the only
/// `Err` is a harness defect ([`EngineError::MissingIndices`]).
pub fn run_sweep_outcome(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> Result<RunOutcome<SweepRow>, EngineError> {
    // fusion is accelerator-independent: solve once, share across workers
    let parts = SweepPartitions::prepare(fwd, train, cfg);
    let eval = SweepEval { fwd, train, parts: &parts, cfg };
    let mut out = Engine::new(cfg.engine()).run_journaled(points, &eval, progress)?;
    // historical row order: inference before training per point, whatever
    // order `cfg.modes` listed them in
    out.rows.sort_by_key(|r| (r.index, r.mode != Mode::Inference));
    Ok(out)
}

/// Legacy-shape adapter: the `(rows, stats)` entry points predate the
/// structured [`RunOutcome`] and keep their fail-loud contract — an
/// engine error or an isolated point failure panics with the structured
/// diagnostic (fault-free runs, the only thing their callers execute,
/// never take these branches).
fn unwrap_outcome<R>(
    what: &str,
    outcome: Result<RunOutcome<R>, EngineError>,
) -> (Vec<R>, CacheStats) {
    let out = outcome.unwrap_or_else(|e| panic!("{what} failed: {e}"));
    if let Some(f) = out.failures.first() {
        panic!(
            "{what} point {} ({}) failed: {} ({} failed point(s) total)",
            f.index,
            f.point_id,
            f.diagnostic,
            out.failures.len()
        );
    }
    (out.rows, out.cache)
}

// ---------------------------------------------------------------------------
// Cluster-scale sweep: deployment points instead of accelerator points
// ---------------------------------------------------------------------------

/// One evaluated deployment point (a row of the Fig 5 data): a DP/PP/TP
/// factorization on a device count and link tier, with the four cluster
/// objectives (iteration latency, energy, per-device memory, cluster
/// size).
#[derive(Debug, Clone)]
pub struct ClusterRow {
    pub index: usize,
    pub label: String,
    pub devices: usize,
    /// Homogeneous rows: the fabric tier swept. Heterogeneous rows: the
    /// bottleneck tier of the placement (slowest used class fabric).
    pub tier: LinkTier,
    pub dp: usize,
    pub pp: usize,
    pub microbatches: usize,
    pub tp: usize,
    /// Stage placement by class name, `|`-joined (e.g. `edge|datacenter`);
    /// empty for homogeneous rows.
    pub placement: String,
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub per_device_mem_bytes: u64,
    pub comm_bytes: f64,
}

impl ClusterRow {
    /// The typed four-objective NSGA-II set of the cluster DSE
    /// (iteration latency, energy, per-device memory, cluster size; all
    /// minimized — `.to_vec()` feeds `pareto_rank0`).
    pub fn objectives(&self) -> Objectives {
        Objectives {
            latency_cycles: self.latency_cycles,
            energy_pj: self.energy_pj,
            memory_bytes: self.per_device_mem_bytes,
            devices: self.devices,
        }
    }

    /// `(dp, pp, tp)` — the strategy factorization, microbatches aside.
    pub fn factorization(&self) -> (usize, usize, usize) {
        (self.dp, self.pp, self.tp)
    }
}

/// Per-worker scratch of the cluster-scale sweep families: the
/// training-graph memo (distinct factorizations mostly share their
/// replica-batch / microbatch sizes, and `builder(batch)` must be a pure
/// function of the batch) plus the stage-cuts memo (deployment points
/// sharing a microbatch graph and stage-class sequence reuse one
/// latency-balanced split — ROADMAP hetero follow-up (d)). Both are
/// memos of pure functions, so they never change a row (the engine's
/// evaluation contract).
#[derive(Default)]
pub struct ClusterScratch {
    graphs: RefCell<HashMap<usize, TrainingGraph>>,
    pub cuts: StageCutsMemo,
}

impl ClusterScratch {
    /// The memoizing view of `build` this worker hands to the strategy
    /// models (`build` must be pure in the batch size). Public so custom
    /// [`Evaluate`] impls — see `examples/multi_device.rs` — can reuse
    /// the scratch instead of re-rolling the memo.
    pub fn graph_builder<'a>(
        &'a self,
        build: &'a (dyn Fn(usize) -> TrainingGraph + Sync),
    ) -> impl Fn(usize) -> TrainingGraph + 'a {
        move |batch: usize| {
            if let Some(tg) = self.graphs.borrow().get(&batch) {
                return tg.clone();
            }
            let tg = build(batch);
            self.graphs.borrow_mut().insert(batch, tg.clone());
            tg
        }
    }
}

/// The homogeneous deployment sweep as an [`Evaluate`] instance: one
/// [`ClusterRow`] per [`ClusterPoint`], via the hybrid strategy model on
/// one accelerator and the point's link tier.
pub struct ClusterEval<'a> {
    pub full_batch: usize,
    pub builder: &'a (dyn Fn(usize) -> TrainingGraph + Sync),
    pub accel: &'a Accelerator,
    pub mapping: MappingConfig,
}

// audit:pure
impl Evaluate for ClusterEval<'_> {
    type Point = ClusterPoint;
    type Row = ClusterRow;
    type Scratch = ClusterScratch;

    fn scratch(&self) -> ClusterScratch {
        ClusterScratch::default()
    }

    fn evaluate(
        &self,
        index: usize,
        p: &ClusterPoint,
        cache: Option<&CostCache>,
        scratch: &mut ClusterScratch,
    ) -> Vec<ClusterRow> {
        let local_builder = scratch.graph_builder(self.builder);
        let r = model_strategy_memo(
            p.strategy(),
            self.full_batch,
            &local_builder,
            self.accel,
            &self.mapping,
            &p.cluster(),
            cache,
            Some(&scratch.cuts),
        );
        vec![ClusterRow {
            index,
            label: p.label(),
            devices: r.devices,
            tier: p.tier,
            dp: p.dp,
            pp: p.pp,
            microbatches: p.microbatches,
            tp: p.tp,
            placement: String::new(),
            latency_cycles: r.latency_cycles,
            energy_pj: r.energy_pj,
            per_device_mem_bytes: r.per_device_mem_bytes,
            comm_bytes: r.comm_bytes,
        }]
    }

    /// The deployment-model roofline ([`model_strategy_bound`]) in the
    /// four-objective cluster geometry: latency/energy are admissible
    /// lower bounds, memory and device count are exact — so a faster
    /// tier twin can prune its slower sibling. Bounds never touch the
    /// cost cache (`None`): pruning must not change what gets cached
    /// for surviving points.
    fn lower_bound(
        &self,
        _index: usize,
        p: &ClusterPoint,
        scratch: &mut ClusterScratch,
    ) -> Option<Vec<Vec<f64>>> {
        let local_builder = scratch.graph_builder(self.builder);
        let r = model_strategy_bound(
            p.strategy(),
            self.full_batch,
            &local_builder,
            self.accel,
            &self.mapping,
            &p.cluster(),
            None,
            Some(&scratch.cuts),
        );
        Some(vec![vec![
            r.latency_cycles,
            r.energy_pj,
            r.per_device_mem_bytes as f64,
            r.devices as f64,
        ]])
    }

    fn row_objectives(&self, row: &ClusterRow) -> Option<Vec<f64>> {
        Some(row.objectives().to_vec())
    }
}

/// Evaluate every [`ClusterPoint`] over the engine's worker pool,
/// sharing one group-cost cache: the per-device stage schedules are pure
/// functions of the stage structure, so factorizations yielding the same
/// stage shape (and the same point on every link tier) hit the same
/// entries. The cache lifecycle (`use_cache`/`cache_dir`/`cache_cap`)
/// and determinism guarantees match [`run_sweep_stats`]; `cfg.mapping`
/// supplies the single-device mapping. `builder(batch)` must be a pure
/// function of the batch size — each worker memoizes it per batch.
pub fn run_cluster_sweep(
    points: &[ClusterPoint],
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    accel: &Accelerator,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> (Vec<ClusterRow>, CacheStats) {
    unwrap_outcome(
        "cluster sweep",
        run_cluster_sweep_outcome(points, full_batch, builder, accel, cfg, progress),
    )
}

/// [`run_cluster_sweep`] with the crash-safety layer and structured
/// degradation — see [`run_sweep_outcome`].
pub fn run_cluster_sweep_outcome(
    points: &[ClusterPoint],
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    accel: &Accelerator,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> Result<RunOutcome<ClusterRow>, EngineError> {
    let eval = ClusterEval { full_batch, builder, accel, mapping: cfg.mapping };
    Engine::new(cfg.engine()).run_journaled(points, &eval, progress)
}

/// The heterogeneous stage-placement sweep as an [`Evaluate`] instance:
/// one [`ClusterRow`] per [`HeteroPoint`], via the placement-aware
/// strategy model on the pool's device classes. Each row's `placement`
/// column records which class hosts which pipeline stage; `tier` is the
/// placement's bottleneck fabric.
pub struct HeteroEval<'a> {
    pub hc: &'a HeteroCluster,
    pub full_batch: usize,
    pub builder: &'a (dyn Fn(usize) -> TrainingGraph + Sync),
    pub mapping: MappingConfig,
}

// audit:pure
impl Evaluate for HeteroEval<'_> {
    type Point = HeteroPoint;
    type Row = ClusterRow;
    type Scratch = ClusterScratch;

    fn scratch(&self) -> ClusterScratch {
        ClusterScratch::default()
    }

    fn evaluate(
        &self,
        index: usize,
        p: &HeteroPoint,
        cache: Option<&CostCache>,
        scratch: &mut ClusterScratch,
    ) -> Vec<ClusterRow> {
        let local_builder = scratch.graph_builder(self.builder);
        let r = model_strategy_hetero_memo(
            p,
            self.full_batch,
            &local_builder,
            &self.mapping,
            self.hc,
            cache,
            Some(&scratch.cuts),
        );
        vec![ClusterRow {
            index,
            label: p.label(self.hc),
            devices: r.devices,
            tier: self.hc.bottleneck_tier(&p.placement),
            dp: p.dp,
            pp: p.pp,
            microbatches: p.microbatches,
            tp: p.tp,
            placement: p.placement_names(self.hc),
            latency_cycles: r.latency_cycles,
            energy_pj: r.energy_pj,
            per_device_mem_bytes: r.per_device_mem_bytes,
            comm_bytes: r.comm_bytes,
        }]
    }

    /// Placement-aware sibling of [`ClusterEval::lower_bound`]
    /// ([`model_strategy_hetero_bound`]): admissible latency/energy,
    /// exact memory and device count, no cache traffic.
    fn lower_bound(
        &self,
        _index: usize,
        p: &HeteroPoint,
        scratch: &mut ClusterScratch,
    ) -> Option<Vec<Vec<f64>>> {
        let local_builder = scratch.graph_builder(self.builder);
        let r = model_strategy_hetero_bound(
            p,
            self.full_batch,
            &local_builder,
            &self.mapping,
            self.hc,
            None,
            Some(&scratch.cuts),
        );
        Some(vec![vec![
            r.latency_cycles,
            r.energy_pj,
            r.per_device_mem_bytes as f64,
            r.devices as f64,
        ]])
    }

    fn row_objectives(&self, row: &ClusterRow) -> Option<Vec<f64>> {
        Some(row.objectives().to_vec())
    }
}

/// Evaluate every [`HeteroPoint`] of a heterogeneous device pool — the
/// placement-aware sibling of [`run_cluster_sweep`], with the same cache
/// lifecycle and determinism guarantees (rows are bit-identical across
/// worker counts and with/without the shared cost cache), through the
/// same [`Engine`] harness.
pub fn run_hetero_sweep(
    points: &[HeteroPoint],
    hc: &HeteroCluster,
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> (Vec<ClusterRow>, CacheStats) {
    unwrap_outcome(
        "hetero sweep",
        run_hetero_sweep_outcome(points, hc, full_batch, builder, cfg, progress),
    )
}

/// [`run_hetero_sweep`] with the crash-safety layer and structured
/// degradation — see [`run_sweep_outcome`].
pub fn run_hetero_sweep_outcome(
    points: &[HeteroPoint],
    hc: &HeteroCluster,
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> Result<RunOutcome<ClusterRow>, EngineError> {
    let space = HeteroSpace { points, cluster: hc };
    let eval = HeteroEval { hc, full_batch, builder, mapping: cfg.mapping };
    Engine::new(cfg.engine()).run_journaled(&space, &eval, progress)
}

/// Pareto front over (latency, energy): indices of non-dominated rows, in
/// ascending index order.
///
/// Sort-then-scan, O(n log n) (§Perf — the previous all-pairs check was
/// O(n²) and ran on every sweep's output and every GA front). Semantics
/// are unchanged: a row survives iff no other row is ≤ in both objectives
/// and < in at least one; exact duplicates of a surviving point all
/// survive (neither dominates the other).
pub fn pareto_front(rows: &[SweepRow]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..rows.len()).collect();
    // total_cmp, not partial_cmp().unwrap(): one NaN objective from a
    // degenerate design point must not abort a multi-hour sweep. NaNs
    // order after +inf, so NaN rows sort last and never displace a real
    // front point.
    idx.sort_by(|&a, &b| {
        rows[a]
            .latency_cycles
            .total_cmp(&rows[b].latency_cycles)
            .then(rows[a].energy_pj.total_cmp(&rows[b].energy_pj))
    });
    let mut front = vec![];
    // min energy among rows with strictly smaller latency
    let mut best_en = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        if rows[idx[i]].latency_cycles.is_nan() {
            // NaN latencies sort after every finite value: nothing from
            // here on can be Pareto-optimal (a NaN-latency row must never
            // enter the front on the strength of a low energy alone)
            break;
        }
        // latency-tie group [i, j), sorted by energy within it
        let mut j = i + 1;
        while j < idx.len()
            && rows[idx[j]].latency_cycles == rows[idx[i]].latency_cycles
        {
            j += 1;
        }
        let group_min = rows[idx[i]].energy_pj;
        if group_min < best_en {
            // survivors: the group's energy minimizers (duplicates included)
            for &k in &idx[i..j] {
                if rows[k].energy_pj == group_min {
                    front.push(k);
                } else {
                    break;
                }
            }
        }
        best_en = best_en.min(group_min);
        i = j;
    }
    front.sort_unstable();
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::workload::models::resnet18;

    fn graphs() -> (Graph, Graph) {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        (fwd, tg.graph)
    }

    #[test]
    fn sweep_covers_all_points_and_modes() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(2000);
        let cfg = SweepConfig { workers: 2, ..Default::default() };
        let mut calls = 0;
        let rows = run_sweep(&points, &fwd, &train, &cfg, |_, _| calls += 1);
        assert_eq!(calls, points.len());
        assert_eq!(rows.len(), points.len() * 2);
        // deterministic ordering
        for (i, chunk) in rows.chunks(2).enumerate() {
            assert_eq!(chunk[0].index, i);
            assert_eq!(chunk[0].mode, Mode::Inference);
            assert_eq!(chunk[1].mode, Mode::Training);
        }
    }

    #[test]
    fn training_costs_more_than_inference() {
        let (fwd, train) = graphs();
        let points = vec![DesignPoint::edge_space(1)[0]];
        let rows = run_sweep(&points, &fwd, &train, &SweepConfig::default(), |_, _| {});
        let inf = &rows[0];
        let tr = &rows[1];
        assert!(tr.latency_cycles > inf.latency_cycles * 1.5);
        assert!(tr.energy_pj > inf.energy_pj * 1.5);
    }

    #[test]
    fn sweep_is_deterministic_across_worker_counts() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(3000);
        let one = run_sweep(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 1, ..Default::default() },
            |_, _| {},
        );
        let four = run_sweep(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 4, ..Default::default() },
            |_, _| {},
        );
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.energy_pj, b.energy_pj);
        }
    }

    /// The retired O(n²) implementation, kept as the semantic oracle.
    fn pareto_front_all_pairs(rows: &[SweepRow]) -> Vec<usize> {
        let mut front = vec![];
        'outer: for (i, r) in rows.iter().enumerate() {
            for (j, o) in rows.iter().enumerate() {
                if i != j
                    && o.latency_cycles <= r.latency_cycles
                    && o.energy_pj <= r.energy_pj
                    && (o.latency_cycles < r.latency_cycles || o.energy_pj < r.energy_pj)
                {
                    continue 'outer;
                }
            }
            front.push(i);
        }
        front
    }

    fn synth_row(latency_cycles: f64, energy_pj: f64) -> SweepRow {
        SweepRow {
            index: 0,
            label: String::new(),
            mode: Mode::Inference,
            total_macs: 0,
            color_axis: 0.0,
            latency_cycles,
            energy_pj,
            peak_dram_bytes: 0,
            utilization: 0.0,
        }
    }

    #[test]
    fn pareto_front_matches_all_pairs_oracle() {
        // crafted ties, duplicates, and a dominated diagonal
        let crafted: Vec<SweepRow> = [
            (1.0, 9.0),
            (2.0, 7.0),
            (2.0, 7.0), // duplicate of a front point: both survive
            (2.0, 8.0), // same latency, worse energy
            (3.0, 7.0), // dominated by (2.0, 7.0)
            (4.0, 4.0),
            (4.0, 9.0),
            (5.0, 4.0), // dominated (ties energy, worse latency)
            (6.0, 1.0),
        ]
        .iter()
        .map(|&(l, e)| synth_row(l, e))
        .collect();
        assert_eq!(pareto_front(&crafted), pareto_front_all_pairs(&crafted));
        assert_eq!(pareto_front(&crafted), vec![0, 1, 2, 5, 8]);
        assert!(pareto_front(&[]).is_empty());

        // and on real sweep output
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(800);
        let rows = run_sweep(&points, &fwd, &train, &SweepConfig::default(), |_, _| {});
        assert_eq!(pareto_front(&rows), pareto_front_all_pairs(&rows));
    }

    #[test]
    fn pareto_front_survives_nan_objectives() {
        // degenerate rows on every axis: pre-fix, the partial_cmp unwrap
        // in the sort aborted the whole sweep's post-processing, and a
        // NaN-latency row with the globally lowest energy entered the
        // front
        let rows: Vec<SweepRow> = [
            (1.0, 1.0),
            (f64::NAN, 0.5),
            (2.0, f64::NAN),
            (2.0, 0.5),
            (f64::NAN, f64::NAN),
            (f64::NAN, 0.1), // lowest energy of all — still not a front point
        ]
        .iter()
        .map(|&(l, e)| synth_row(l, e))
        .collect();
        let front = pareto_front(&rows);
        assert_eq!(front, vec![0, 3], "finite front points survive, NaN rows drop");
    }

    #[test]
    fn persisted_sweep_is_bit_identical_and_warmer_on_the_second_run() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(2500);
        let dir = std::env::temp_dir()
            .join(format!("monet_sweep_cache_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = SweepConfig {
            workers: 2,
            cache_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (r1, s1) = run_sweep_stats(&points, &fwd, &train, &cfg, |_, _| {});
        let (r2, s2) = run_sweep_stats(&points, &fwd, &train, &cfg, |_, _| {});
        // the warm-loaded second run recomputes nothing and hits strictly
        // more often than the cold run
        assert_eq!(s2.misses, 0, "warm run recomputed group costs: {s2:?}");
        assert!(s2.hit_rate() > s1.hit_rate(), "warm {s2:?} !> cold {s1:?}");
        assert_eq!(s1.entries, s2.entries);
        // and rows are bit-identical across the restart
        assert_eq!(r1.len(), r2.len());
        for (a, b) in r1.iter().zip(&r2) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.peak_dram_bytes, b.peak_dram_bytes);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cached_and_uncached_sweeps_agree_bitwise() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(1200);
        let (cached, stats) = run_sweep_stats(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 4, use_cache: true, ..Default::default() },
            |_, _| {},
        );
        let (plain, no_stats) = run_sweep_stats(
            &points,
            &fwd,
            &train,
            &SweepConfig { workers: 4, use_cache: false, ..Default::default() },
            |_, _| {},
        );
        assert!(stats.hits > 0, "shared cache never hit");
        assert_eq!(no_stats, CacheStats::default());
        assert_eq!(cached.len(), plain.len());
        for (a, b) in cached.iter().zip(&plain) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.peak_dram_bytes, b.peak_dram_bytes);
        }
    }

    #[test]
    fn cluster_sweep_is_deterministic_and_complete_across_worker_counts() {
        use crate::hardware::presets::EdgeTpuParams;
        use crate::parallelism::LinkTier;

        let space = super::super::space::ClusterSpace {
            device_counts: vec![1, 2],
            tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
            microbatches: vec![2],
        };
        let points = space.enumerate();
        assert!(points.len() >= 6);
        let accel = EdgeTpuParams::baseline().build();
        let cfg = MappingConfig::edge_tpu_default();
        let run = |workers: usize| {
            let mut calls = 0usize;
            let (rows, stats) = run_cluster_sweep(
                &points,
                8,
                &crate::figures::cluster_resnet18_builder,
                &accel,
                &SweepConfig { workers, mapping: cfg, ..Default::default() },
                |_, _| calls += 1,
            );
            assert_eq!(calls, points.len());
            (rows, stats)
        };
        let (one, s1) = run(1);
        let (four, _) = run(4);
        assert_eq!(one.len(), points.len());
        assert!(s1.hits > 0, "tier-repeated stage schedules must share costs");
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            assert_eq!(a.index, i);
            assert_eq!(a.label, points[i].label());
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
            assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
        }
        // the row geometry matches the point geometry
        for (p, r) in points.iter().zip(&one) {
            assert_eq!(r.devices, p.devices);
            assert_eq!(r.factorization(), (p.dp, p.pp, p.tp));
            assert_eq!(r.objectives().to_vec().len(), 4);
            assert_eq!(r.objectives().devices, r.devices);
        }
    }

    #[test]
    fn hetero_sweep_is_deterministic_and_complete_across_worker_counts() {
        use crate::parallelism::{DeviceClass, HeteroCluster};

        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let points = super::super::space::ClusterSpace::enumerate_hetero(&hc, &[2]);
        assert!(points.iter().any(|p| p.is_mixed()));
        let run = |workers: usize| {
            let mut calls = 0usize;
            let (rows, stats) = run_hetero_sweep(
                &points,
                &hc,
                4,
                &crate::figures::cluster_resnet18_builder,
                &SweepConfig {
                    workers,
                    mapping: MappingConfig::edge_tpu_default(),
                    ..Default::default()
                },
                |_, _| calls += 1,
            );
            assert_eq!(calls, points.len());
            (rows, stats)
        };
        let (one, s1) = run(1);
        let (four, _) = run(4);
        assert_eq!(one.len(), points.len());
        assert!(s1.hits > 0, "placements sharing stage shapes must share costs");
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            assert_eq!(a.index, i);
            assert_eq!(a.label, points[i].label(&hc));
            assert_eq!(a.placement, points[i].placement_names(&hc));
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.per_device_mem_bytes, b.per_device_mem_bytes);
            assert_eq!(a.comm_bytes.to_bits(), b.comm_bytes.to_bits());
        }
    }

    #[test]
    fn pareto_front_is_nondominated() {
        let (fwd, train) = graphs();
        let points = DesignPoint::edge_space(1000);
        let rows = run_sweep(&points, &fwd, &train, &SweepConfig::default(), |_, _| {});
        let inf_rows: Vec<SweepRow> =
            rows.iter().filter(|r| r.mode == Mode::Inference).cloned().collect();
        let front = pareto_front(&inf_rows);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                if i != j {
                    let (a, b) = (&inf_rows[i], &inf_rows[j]);
                    assert!(
                        !(a.latency_cycles <= b.latency_cycles
                            && a.energy_pj <= b.energy_pj
                            && (a.latency_cycles < b.latency_cycles
                                || a.energy_pj < b.energy_pj))
                    );
                }
            }
        }
    }
}
