//! Crash-safe run journaling for the DSE engine (and the GA's
//! per-generation checkpoints): an **append-only, checksummed** record log
//! in `--run-dir` that makes a killed multi-hour sweep resumable.
//!
//! ## File layout
//!
//! Every journal opens with a 56-byte header —
//!
//! ```text
//! magic(8) | format u32 | contract u32 | hasher fingerprint u128 |
//! space digest u128 | fnv64(first 48 bytes) u64
//! ```
//!
//! — the same three structural guards as the snapshot-header rule in
//! [`crate::eval::persist`] (format version, hasher fingerprint,
//! [`crate::eval::CACHE_CONTRACT_VERSION`]) **plus a design-space
//! digest**: a journal is only replayable against the identical,
//! identically-ordered point set, so [`space_digest`] folds every
//! `point_id` into the header and a resumed run against a different
//! space/config rejects the file wholesale. Unlike snapshots, an
//! append-only file cannot carry a whole-file checksum trailer, so the
//! header checksums itself and each record carries its own trailer:
//!
//! ```text
//! payload_len u32 | payload | fnv64(payload) u64
//! ```
//!
//! ## Torn tails
//!
//! A crash mid-append leaves a torn final record. Replay parses records
//! until the first length/checksum violation, truncates the file back to
//! the last good record boundary, and returns only the valid prefix —
//! so `--resume` after a kill at *any* byte offset recovers cleanly
//! (pinned by `tests/fault_injection.rs`, which truncates at every byte).
//!
//! ## Hot-path cost
//!
//! Appends are buffered writes with a `flush` (no per-record `fsync`):
//! a record survives a process kill once the OS has it, which is the
//! failure model this PR targets (killed runs, panics, torn writes —
//! not power loss). `BENCH_dse.json` pins the overhead.

use std::fs;
use std::io::{self, Seek, Write};
use std::path::Path;

use super::engine::DesignSpace;
use super::sweep::{ClusterRow, Mode, SweepRow};
use crate::eval::cost_cache::StructuralHasher;
use crate::eval::persist::{
    fnv64, hasher_fingerprint, put_f64, put_str, put_u128, put_u32, put_u64, Reader,
};
use crate::parallelism::LinkTier;

/// Byte-layout version of the journal codec.
pub const JOURNAL_FORMAT_VERSION: u32 = 1;

/// File name of the engine's per-point run journal inside a `--run-dir`.
pub const RUN_JOURNAL_FILE: &str = "run_journal.bin";

/// File name of the GA's per-generation journal inside a `--run-dir`.
pub const GA_JOURNAL_FILE: &str = "ga_journal.bin";

/// Magic of the per-point run journal.
pub const RUN_MAGIC: &[u8; 8] = b"MONETJN\0";

/// Magic of the GA generation journal (distinct from the warm-start
/// snapshot's `MONETGA\0`).
pub const GA_JOURNAL_MAGIC: &[u8; 8] = b"MONETGJ\0";

/// Total header size: magic(8) + format(4) + contract(4) + fingerprint(16)
/// + space digest(16) + header checksum(8).
pub const HEADER_LEN: usize = 56;

/// Sanity cap on one record's payload (a flipped length-prefix byte must
/// not make replay attempt a multi-gigabyte read).
const MAX_RECORD_LEN: usize = 1 << 26; // 64 MiB

/// What the journal remembers about one completed design point: its rows,
/// the diagnostic of its isolated failure, or the fact that the
/// bound-based pruner skipped it. Replay restores any of the three — a
/// resumed run neither re-evaluates nor forgets a failed or skipped
/// point, so `--resume` of a pruned run reproduces the uninterrupted
/// run's front bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub enum PointRecord<R> {
    Rows(Vec<R>),
    Failed(String),
    /// The engine's pruner proved the point's rows dominated and never
    /// evaluated it (see `Evaluate::lower_bound`).
    Skipped,
}

/// A row type the engine can journal: a self-contained binary encoding
/// whose decode is bit-exact (floats round-trip through `to_bits`) and
/// never panics on torn input (every accessor is bounds-checked).
pub trait JournalRow: Sized {
    fn encode(&self, buf: &mut Vec<u8>);
    fn decode(r: &mut Reader<'_>) -> Option<Self>;
}

impl JournalRow for SweepRow {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.index as u64);
        put_str(buf, &self.label);
        buf.push(match self.mode {
            Mode::Inference => 0,
            Mode::Training => 1,
        });
        put_u64(buf, self.total_macs);
        put_f64(buf, self.color_axis);
        put_f64(buf, self.latency_cycles);
        put_f64(buf, self.energy_pj);
        put_u64(buf, self.peak_dram_bytes);
        put_f64(buf, self.utilization);
    }

    fn decode(r: &mut Reader<'_>) -> Option<SweepRow> {
        Some(SweepRow {
            index: r.u64()? as usize,
            label: r.str()?,
            mode: match r.take(1)?[0] {
                0 => Mode::Inference,
                1 => Mode::Training,
                _ => return None,
            },
            total_macs: r.u64()?,
            color_axis: r.f64()?,
            latency_cycles: r.f64()?,
            energy_pj: r.f64()?,
            peak_dram_bytes: r.u64()?,
            utilization: r.f64()?,
        })
    }
}

impl JournalRow for ClusterRow {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.index as u64);
        put_str(buf, &self.label);
        put_u64(buf, self.devices as u64);
        buf.push(self.tier.rank());
        put_u64(buf, self.dp as u64);
        put_u64(buf, self.pp as u64);
        put_u64(buf, self.microbatches as u64);
        put_u64(buf, self.tp as u64);
        put_str(buf, &self.placement);
        put_f64(buf, self.latency_cycles);
        put_f64(buf, self.energy_pj);
        put_u64(buf, self.per_device_mem_bytes);
        put_f64(buf, self.comm_bytes);
    }

    fn decode(r: &mut Reader<'_>) -> Option<ClusterRow> {
        Some(ClusterRow {
            index: r.u64()? as usize,
            label: r.str()?,
            devices: r.u64()? as usize,
            tier: *LinkTier::all().get(r.take(1)?[0] as usize)?,
            dp: r.u64()? as usize,
            pp: r.u64()? as usize,
            microbatches: r.u64()? as usize,
            tp: r.u64()? as usize,
            placement: r.str()?,
            latency_cycles: r.f64()?,
            energy_pj: r.f64()?,
            per_device_mem_bytes: r.u64()?,
            comm_bytes: r.f64()?,
        })
    }
}

/// Digest of a design space's identity: its length plus every `point_id`,
/// in order, through [`StructuralHasher`]. Equal iff the space enumerates
/// the same points in the same order — the replay-compatibility guard the
/// journal header carries.
pub fn space_digest<S: DesignSpace + ?Sized>(space: &S) -> u128 {
    use std::hash::{Hash, Hasher as _};
    let mut h = StructuralHasher::new();
    let n = space.len();
    n.hash(&mut h);
    for i in 0..n {
        space.point_id(i).hash(&mut h);
    }
    h.finish128()
}

fn header_bytes(magic: &[u8; 8], digest: u128) -> Vec<u8> {
    let mut buf = Vec::with_capacity(HEADER_LEN);
    buf.extend_from_slice(magic);
    put_u32(&mut buf, JOURNAL_FORMAT_VERSION);
    put_u32(&mut buf, crate::eval::CACHE_CONTRACT_VERSION);
    put_u128(&mut buf, hasher_fingerprint());
    put_u128(&mut buf, digest);
    let sum = fnv64(&buf);
    put_u64(&mut buf, sum);
    debug_assert_eq!(buf.len(), HEADER_LEN);
    buf
}

fn header_is_valid(buf: &[u8], magic: &[u8; 8], digest: u128) -> bool {
    buf.len() >= HEADER_LEN && buf[..HEADER_LEN] == header_bytes(magic, digest)[..]
}

/// An open, append-position journal. Records stream through
/// [`JournalFile::append_record`]; the handle is used from the engine's
/// serial sink (one writer, no locks).
pub struct JournalFile {
    file: io::BufWriter<fs::File>,
}

impl JournalFile {
    /// Append one checksummed record and flush it to the OS. Consults the
    /// fault-injection hooks ([`crate::util::fault`]) so tests can fail
    /// or corrupt exactly the n-th journal write.
    pub fn append_record(&mut self, payload: &[u8]) -> io::Result<()> {
        crate::util::fault::write_gate("journal")?;
        let mut rec = Vec::with_capacity(payload.len() + 12);
        put_u32(&mut rec, payload.len() as u32);
        rec.extend_from_slice(payload);
        put_u64(&mut rec, fnv64(payload));
        crate::util::fault::maybe_flip(&mut rec);
        self.file.write_all(&rec)?;
        self.file.flush()
    }
}

/// Parse the record region of `buf` (everything after the header):
/// returns the valid payloads and the byte offset just past the last
/// good record — the truncation point for a torn tail.
fn parse_records(buf: &[u8]) -> (Vec<Vec<u8>>, usize) {
    let mut payloads = Vec::new();
    let mut pos = HEADER_LEN;
    loop {
        let Some(len_bytes) = buf.get(pos..pos + 4) else { break };
        let len = u32::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
        if len > MAX_RECORD_LEN {
            break;
        }
        let Some(payload) = buf.get(pos + 4..pos + 4 + len) else { break };
        let Some(sum_bytes) = buf.get(pos + 4 + len..pos + 12 + len) else { break };
        if fnv64(payload) != u64::from_le_bytes(sum_bytes.try_into().unwrap()) {
            break;
        }
        payloads.push(payload.to_vec());
        pos += 12 + len;
    }
    (payloads, pos)
}

/// Open (or create) the journal at `path`.
///
/// * `resume == false`: start a fresh journal (any existing file is
///   overwritten) and return no replayed payloads.
/// * `resume == true`: validate the header against `magic`/`digest` and
///   the structural guards; replay every checksummed record, truncating a
///   torn tail back to the last good record boundary. A header that fails
///   validation (different space, stale contract, bit rot) quarantines
///   the file to a `.corrupt` sidecar with a warning and starts fresh —
///   resuming against the wrong journal must lose the journal, never
///   corrupt the run.
///
/// Returns the replayed payloads plus the handle positioned for appends.
pub fn open_journal(
    path: &Path,
    magic: &[u8; 8],
    digest: u128,
    resume: bool,
) -> io::Result<(Vec<Vec<u8>>, JournalFile)> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    if resume {
        if let Ok(buf) = fs::read(path) {
            if header_is_valid(&buf, magic, digest) {
                let (payloads, valid_len) = parse_records(&buf);
                let mut file = fs::OpenOptions::new().write(true).open(path)?;
                if valid_len < buf.len() {
                    eprintln!(
                        "warning: journal {} has a torn tail ({} trailing bytes); \
                         truncating to the last good record boundary",
                        path.display(),
                        buf.len() - valid_len
                    );
                    file.set_len(valid_len as u64)?;
                }
                file.seek(io::SeekFrom::End(0))?;
                return Ok((payloads, JournalFile { file: io::BufWriter::new(file) }));
            }
            // a file exists but is not our journal (foreign space, stale
            // contract, corrupt header): quarantine, never overwrite
            let quarantine = path.with_extension("bin.corrupt");
            match fs::rename(path, &quarantine) {
                Ok(()) => eprintln!(
                    "warning: cannot resume from journal {} (wrong design space, stale \
                     format/contract, or corrupt header); quarantined to {} and starting fresh",
                    path.display(),
                    quarantine.display()
                ),
                Err(e) => eprintln!(
                    "warning: cannot resume from journal {} and could not quarantine it \
                     ({e}); starting fresh",
                    path.display()
                ),
            }
        }
    }
    let mut file = fs::File::create(path)?;
    file.write_all(&header_bytes(magic, digest))?;
    file.flush()?;
    Ok((Vec::new(), JournalFile { file: io::BufWriter::new(file) }))
}

/// The clean record boundaries of the journal at `path`: byte offsets a
/// crash could truncate the file to and still leave every preceding
/// record replayable — `HEADER_LEN`, then the end of each valid record.
/// Empty when the file has no valid header. The crash-at-every-boundary
/// recovery tests iterate exactly these.
pub fn journal_record_bounds(path: &Path) -> io::Result<Vec<u64>> {
    let buf = fs::read(path)?;
    if buf.len() < HEADER_LEN {
        return Ok(Vec::new());
    }
    let mut bounds = vec![HEADER_LEN as u64];
    let mut pos = HEADER_LEN;
    loop {
        let Some(len_bytes) = buf.get(pos..pos + 4) else { break };
        let len = u32::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
        if len > MAX_RECORD_LEN || buf.get(pos + 4..pos + 12 + len).is_none() {
            break;
        }
        pos += 12 + len;
        bounds.push(pos as u64);
    }
    Ok(bounds)
}

/// Encode one completed point for the run journal: which index finished,
/// and either its rows or its failure diagnostic.
pub fn encode_point_record<R: JournalRow>(index: usize, rec: &PointRecord<R>) -> Vec<u8> {
    let mut buf = Vec::new();
    match rec {
        PointRecord::Rows(rows) => {
            buf.push(0);
            put_u64(&mut buf, index as u64);
            put_u32(&mut buf, rows.len() as u32);
            for row in rows {
                row.encode(&mut buf);
            }
        }
        PointRecord::Failed(diag) => {
            buf.push(1);
            put_u64(&mut buf, index as u64);
            put_str(&mut buf, diag);
        }
        // kind 2 is additive: readers predating it decode the record to
        // `None` and simply re-evaluate the point, so the byte format
        // stays at JOURNAL_FORMAT_VERSION 1
        PointRecord::Skipped => {
            buf.push(2);
            put_u64(&mut buf, index as u64);
        }
    }
    buf
}

/// Inverse of [`encode_point_record`]; `None` on any malformed payload
/// (replay then simply re-evaluates the point).
pub fn decode_point_record<R: JournalRow>(payload: &[u8]) -> Option<(usize, PointRecord<R>)> {
    let mut r = Reader::new(payload);
    let kind = r.take(1)?[0];
    let index = r.u64()? as usize;
    let rec = match kind {
        0 => {
            let n = r.u32()? as usize;
            let mut rows = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                rows.push(R::decode(&mut r)?);
            }
            PointRecord::Rows(rows)
        }
        1 => PointRecord::Failed(r.str()?),
        2 => PointRecord::Skipped,
        _ => return None,
    };
    if !r.exhausted() {
        return None;
    }
    Some((index, rec))
}

/// A genome type the GA journal can persist inside a checkpoint record.
/// Same contract as [`JournalRow`]: a self-contained binary encoding whose
/// decode is bit-exact and never panics on torn input (every accessor is
/// bounds-checked). Implemented for the boolean checkpointing genome (the
/// historical byte layout, unchanged) and for
/// [`crate::ga::DeploymentGenome`].
pub trait GenomeCodec: Sized {
    fn encode(&self, buf: &mut Vec<u8>);
    fn decode(r: &mut Reader<'_>) -> Option<Self>;
}

/// The boolean checkpointing genome: `width u32 | one byte per bit`.
/// Byte-identical to the pre-generification hard-coded codec, so GA
/// journals written before this refactor replay unchanged.
impl GenomeCodec for Vec<bool> {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u32(buf, self.len() as u32);
        buf.extend(self.iter().map(|&b| b as u8));
    }

    fn decode(r: &mut Reader<'_>) -> Option<Self> {
        let width = r.u32()? as usize;
        Some(r.take(width)?.iter().map(|&b| b != 0).collect())
    }
}

/// `dp/pp/m/tp u64 ×4 | n_stages u32 | class index u32 per stage`.
impl GenomeCodec for crate::ga::DeploymentGenome {
    fn encode(&self, buf: &mut Vec<u8>) {
        put_u64(buf, self.dp as u64);
        put_u64(buf, self.pp as u64);
        put_u64(buf, self.microbatches as u64);
        put_u64(buf, self.tp as u64);
        put_u32(buf, self.placement.len() as u32);
        for &c in &self.placement {
            put_u32(buf, c as u32);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Option<Self> {
        let dp = r.u64()? as usize;
        let pp = r.u64()? as usize;
        let microbatches = r.u64()? as usize;
        let tp = r.u64()? as usize;
        let n = r.u32()? as usize;
        let mut placement = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            placement.push(r.u32()? as usize);
        }
        Some(crate::ga::DeploymentGenome { dp, pp, microbatches, tp, placement })
    }
}

/// Encode one GA generation checkpoint for the GA journal. Generic over
/// the genome via [`GenomeCodec`]; for `Vec<bool>` the bytes are
/// identical to the pre-generification codec.
pub fn encode_ga_checkpoint<G: GenomeCodec>(cp: &crate::ga::nsga2::GaCheckpoint<G>) -> Vec<u8> {
    let mut buf = Vec::new();
    put_u64(&mut buf, cp.generation as u64);
    for s in cp.rng {
        put_u64(&mut buf, s);
    }
    put_u32(&mut buf, cp.population.len() as u32);
    for (genome, objs) in &cp.population {
        genome.encode(&mut buf);
        put_u32(&mut buf, objs.len() as u32);
        for &o in objs {
            put_f64(&mut buf, o);
        }
    }
    buf
}

/// Inverse of [`encode_ga_checkpoint`]; `None` on any malformed payload.
pub fn decode_ga_checkpoint<G: GenomeCodec>(
    payload: &[u8],
) -> Option<crate::ga::nsga2::GaCheckpoint<G>> {
    let mut r = Reader::new(payload);
    let generation = r.u64()? as usize;
    let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let n = r.u32()? as usize;
    let mut population = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let genome = G::decode(&mut r)?;
        let n_obj = r.u32()? as usize;
        let mut objs = Vec::with_capacity(n_obj.min(4096));
        for _ in 0..n_obj {
            objs.push(r.f64()?);
        }
        population.push((genome, objs));
    }
    if !r.exhausted() {
        return None;
    }
    Some(crate::ga::nsga2::GaCheckpoint { generation, rng, population })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("monet_journal_{tag}_{}", std::process::id()));
        fs::remove_dir_all(&d).ok();
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_payloads(path: &Path, digest: u128, payloads: &[&[u8]]) {
        let (_, mut j) = open_journal(path, RUN_MAGIC, digest, false).unwrap();
        for p in payloads {
            j.append_record(p).unwrap();
        }
    }

    #[test]
    fn journal_round_trips_records_across_reopen() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join(RUN_JOURNAL_FILE);
        write_payloads(&path, 7, &[b"alpha", b"", b"gamma-record"]);
        let (replayed, mut j) = open_journal(&path, RUN_MAGIC, 7, true).unwrap();
        assert_eq!(replayed, vec![b"alpha".to_vec(), b"".to_vec(), b"gamma-record".to_vec()]);
        // appends after a resume land after the replayed records
        j.append_record(b"delta").unwrap();
        drop(j);
        let (again, _) = open_journal(&path, RUN_MAGIC, 7, true).unwrap();
        assert_eq!(again.len(), 4);
        assert_eq!(again[3], b"delta".to_vec());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_to_the_last_good_record() {
        let dir = tmp_dir("torn");
        let path = dir.join(RUN_JOURNAL_FILE);
        write_payloads(&path, 1, &[b"one", b"two"]);
        let full = fs::read(&path).unwrap();
        let bounds = journal_record_bounds(&path).unwrap();
        assert_eq!(bounds.len(), 3, "header + two record ends");
        assert_eq!(*bounds.last().unwrap() as usize, full.len());
        // every truncation point recovers the records wholly before it
        for cut in HEADER_LEN..=full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            let (replayed, _) = open_journal(&path, RUN_MAGIC, 1, true).unwrap();
            let expect = bounds.iter().filter(|&&b| b as usize <= cut).count() - 1;
            assert_eq!(replayed.len(), expect, "cut at byte {cut}");
            let now = fs::metadata(&path).unwrap().len();
            assert!(bounds.contains(&now), "cut at {cut} left a non-boundary length {now}");
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_digest_or_magic_quarantines_and_starts_fresh() {
        let dir = tmp_dir("digest");
        let path = dir.join(RUN_JOURNAL_FILE);
        write_payloads(&path, 42, &[b"rec"]);
        // same file, different design space → nothing replays, evidence kept
        let (replayed, _) = open_journal(&path, RUN_MAGIC, 43, true).unwrap();
        assert!(replayed.is_empty());
        assert!(path.with_extension("bin.corrupt").exists());

        write_payloads(&path, 42, &[b"rec"]);
        let (replayed, _) = open_journal(&path, GA_JOURNAL_MAGIC, 42, true).unwrap();
        assert!(replayed.is_empty(), "foreign magic must not replay");
        // a non-resume open always starts fresh
        write_payloads(&path, 42, &[b"rec"]);
        let (replayed, _) = open_journal(&path, RUN_MAGIC, 42, false).unwrap();
        assert!(replayed.is_empty());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_record_stops_replay_at_the_previous_boundary() {
        let dir = tmp_dir("corrupt");
        let path = dir.join(RUN_JOURNAL_FILE);
        write_payloads(&path, 9, &[b"good-one", b"good-two"]);
        let bounds = journal_record_bounds(&path).unwrap();
        let mut buf = fs::read(&path).unwrap();
        // flip a byte inside the second record's payload
        let off = bounds[1] as usize + 5;
        buf[off] ^= 0x01;
        fs::write(&path, &buf).unwrap();
        let (replayed, _) = open_journal(&path, RUN_MAGIC, 9, true).unwrap();
        assert_eq!(replayed, vec![b"good-one".to_vec()]);
        assert_eq!(fs::metadata(&path).unwrap().len(), bounds[1]);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_row_encoding_is_bit_exact() {
        let row = SweepRow {
            index: 12,
            label: "pe16x16_l4MiB".into(),
            mode: Mode::Training,
            total_macs: 123_456_789,
            color_axis: 0.125,
            latency_cycles: f64::from_bits(0x400921FB54442D18),
            energy_pj: 1.5e12,
            peak_dram_bytes: u64::MAX / 3,
            utilization: 0.875,
        };
        let mut buf = Vec::new();
        row.encode(&mut buf);
        let mut r = Reader::new(&buf);
        let back = SweepRow::decode(&mut r).unwrap();
        assert!(r.exhausted());
        assert_eq!(back.index, row.index);
        assert_eq!(back.label, row.label);
        assert_eq!(back.mode, row.mode);
        assert_eq!(back.total_macs, row.total_macs);
        assert_eq!(back.latency_cycles.to_bits(), row.latency_cycles.to_bits());
        assert_eq!(back.energy_pj.to_bits(), row.energy_pj.to_bits());
        assert_eq!(back.peak_dram_bytes, row.peak_dram_bytes);
        // torn input decodes to None, never panics
        for cut in 0..buf.len() {
            assert!(SweepRow::decode(&mut Reader::new(&buf[..cut])).is_none());
        }
    }

    #[test]
    fn cluster_row_encoding_round_trips_every_tier() {
        for tier in LinkTier::all() {
            let row = ClusterRow {
                index: 3,
                label: format!("d4_{}_dp2", tier.as_str()),
                devices: 4,
                tier,
                dp: 2,
                pp: 2,
                microbatches: 8,
                tp: 1,
                placement: "edge|datacenter".into(),
                latency_cycles: 1e9,
                energy_pj: 2e12,
                per_device_mem_bytes: 1 << 33,
                comm_bytes: 3.5e8,
            };
            let mut buf = Vec::new();
            row.encode(&mut buf);
            let back = ClusterRow::decode(&mut Reader::new(&buf)).unwrap();
            assert_eq!(back.tier, tier);
            assert_eq!(back.label, row.label);
            assert_eq!(back.placement, row.placement);
            assert_eq!(back.latency_cycles.to_bits(), row.latency_cycles.to_bits());
            assert_eq!(back.per_device_mem_bytes, row.per_device_mem_bytes);
        }
    }

    #[test]
    fn point_records_round_trip_rows_and_failures() {
        let rows = vec![
            SweepRow {
                index: 5,
                label: "a".into(),
                mode: Mode::Inference,
                total_macs: 1,
                color_axis: 0.0,
                latency_cycles: 2.0,
                energy_pj: 3.0,
                peak_dram_bytes: 4,
                utilization: 0.5,
            };
            2
        ];
        let payload = encode_point_record(5, &PointRecord::Rows(rows.clone()));
        let (idx, rec) = decode_point_record::<SweepRow>(&payload).unwrap();
        assert_eq!(idx, 5);
        assert_eq!(rec, PointRecord::Rows(rows));

        let payload =
            encode_point_record::<SweepRow>(9, &PointRecord::Failed("boom at layer 3".into()));
        let (idx, rec) = decode_point_record::<SweepRow>(&payload).unwrap();
        assert_eq!(idx, 9);
        assert_eq!(rec, PointRecord::Failed("boom at layer 3".into()));
        // malformed kind byte
        let mut bad = payload.clone();
        bad[0] = 7;
        assert!(decode_point_record::<SweepRow>(&bad).is_none());

        // the pruner's skipped-point record (kind 2)
        let payload = encode_point_record::<SweepRow>(17, &PointRecord::Skipped);
        let (idx, rec) = decode_point_record::<SweepRow>(&payload).unwrap();
        assert_eq!(idx, 17);
        assert_eq!(rec, PointRecord::Skipped);
        for cut in 0..payload.len() {
            assert!(decode_point_record::<SweepRow>(&payload[..cut]).is_none());
        }
    }

    #[test]
    fn ga_checkpoint_round_trips_bit_exact() {
        let cp = crate::ga::nsga2::GaCheckpoint {
            generation: 11,
            rng: [1, u64::MAX, 3, 0xDEAD_BEEF],
            population: vec![
                (vec![true, false, true], vec![1.5, f64::from_bits(0x7FF0000000000000)]),
                (vec![false; 5], vec![0.0, -0.0, 2.5]),
            ],
        };
        let payload = encode_ga_checkpoint(&cp);
        let back = decode_ga_checkpoint::<Vec<bool>>(&payload).unwrap();
        assert_eq!(back.generation, cp.generation);
        assert_eq!(back.rng, cp.rng);
        assert_eq!(back.population.len(), cp.population.len());
        for ((ga, oa), (gb, ob)) in cp.population.iter().zip(&back.population) {
            assert_eq!(ga, gb);
            let bits_a: Vec<u64> = oa.iter().map(|o| o.to_bits()).collect();
            let bits_b: Vec<u64> = ob.iter().map(|o| o.to_bits()).collect();
            assert_eq!(bits_a, bits_b);
        }
        for cut in 0..payload.len() {
            assert!(decode_ga_checkpoint::<Vec<bool>>(&payload[..cut]).is_none());
        }
    }

    #[test]
    fn deployment_checkpoint_round_trips_bit_exact() {
        use crate::ga::DeploymentGenome;
        let cp = crate::ga::nsga2::GaCheckpoint {
            generation: 3,
            rng: [9, 0, u64::MAX, 0xC0DE],
            population: vec![
                (
                    DeploymentGenome {
                        dp: 4,
                        pp: 3,
                        microbatches: 8,
                        tp: 2,
                        placement: vec![0, 1, 1],
                    },
                    vec![10.0, -0.0, f64::INFINITY, 256.0],
                ),
                (
                    DeploymentGenome {
                        dp: 1,
                        pp: 1,
                        microbatches: 1,
                        tp: 1,
                        placement: vec![2],
                    },
                    vec![1.0],
                ),
            ],
        };
        let payload = encode_ga_checkpoint(&cp);
        let back = decode_ga_checkpoint::<DeploymentGenome>(&payload).unwrap();
        assert_eq!(back, cp);
        for cut in 0..payload.len() {
            assert!(decode_ga_checkpoint::<DeploymentGenome>(&payload[..cut]).is_none());
        }
    }

    #[test]
    fn space_digest_tracks_point_identity_and_order() {
        struct Ids(Vec<&'static str>);
        impl DesignSpace for Ids {
            type Point = &'static str;
            fn points(&self) -> &[&'static str] {
                &self.0
            }
            fn point_id(&self, index: usize) -> String {
                self.0[index].to_string()
            }
        }
        let a = space_digest(&Ids(vec!["x", "y"]));
        assert_eq!(a, space_digest(&Ids(vec!["x", "y"])));
        assert_ne!(a, space_digest(&Ids(vec!["y", "x"])), "order matters");
        assert_ne!(a, space_digest(&Ids(vec!["x", "y", "z"])));
        assert_ne!(a, space_digest(&Ids(vec![])));
    }
}
