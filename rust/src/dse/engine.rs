//! The unified DSE evaluation engine: **one** generic worker-pool harness
//! behind every experiment in the repo.
//!
//! Before this module, the repo carried four hand-rolled copies of the
//! same orchestration — `run_sweep_stats` (single-device accelerator
//! points), `run_cluster_sweep` (homogeneous deployments),
//! `run_hetero_sweep` (stage-placement deployments, cross-noted as a
//! line-for-line mirror of the previous one) and the NSGA-II GA's
//! per-generation batch evaluator — each re-implementing the worker
//! pool, the cost-cache lifecycle and the determinism guarantees by
//! hand. They are now all instances of this API (see
//! [`super::sweep::SweepEval`], [`super::sweep::ClusterEval`],
//! [`super::sweep::HeteroEval`] and [`map_parallel`] in
//! `ga::nsga2::evaluate_batch`), so the next search dimension lands as
//! one [`DesignSpace`] + [`Evaluate`] pair instead of a fifth fork.
//!
//! ## The three pieces
//!
//! * [`DesignSpace`] — a finite, **deterministically ordered** set of
//!   points with **stable ids**: enumerating the same space twice yields
//!   the same points in the same order, and `point_id(i)` is unique
//!   within the space and stable across runs/builds (it names rows in
//!   CSVs, caches and golden tests).
//! * [`Evaluate`] — how one point becomes result rows. One instance is
//!   shared by every worker (`&self`), plus a per-worker [`Evaluate::Scratch`]
//!   for memos that must not be contended across threads.
//! * [`Engine`] — the harness. [`Engine::run`] owns the worker pool
//!   (work-stealing index over scoped threads), the per-worker scratch,
//!   the shared [`CostCache`] **lifecycle** (`use_cache` /
//!   `cache_dir` / `cache_cap` — open, warm-load, bound, persist; the
//!   `--no-cache` escape hatch wins over persistence and skips both load
//!   and save), the progress callback, the cache counters, and the
//!   deterministic result ordering.
//!
//! ## Crash safety and fault isolation
//!
//! [`Engine::run_journaled`] adds the resilience layer (`--run-dir` /
//! `--resume`): every completed point is appended to a checksummed
//! [`super::journal`] record, so a killed run resumes bit-identically,
//! replaying completed points instead of re-evaluating them. Independent
//! of journaling, every per-point evaluation runs inside a
//! `catch_unwind` fence: one poisoned point becomes a diagnostic-carrying
//! [`PointFailure`] in the [`RunOutcome`] while the rest of the sweep
//! completes (see *Failure semantics* on [`Engine::run`]).
//!
//! ## The evaluation contract (what an [`Evaluate`] impl may NOT read)
//!
//! Mirroring the `eval` cost-cache soundness contract
//! (`rust/src/eval/mod.rs`), `Evaluate::evaluate` must be a **pure
//! function** of `(index, point, &self)`. It may not read:
//!
//! * worker identity, thread ids, or how points were distributed over
//!   the pool;
//! * wall-clock time, environment variables, or any global mutable
//!   state;
//! * results of *other* points (each point must evaluate as if alone);
//! * the scratch, except as a **memo of pure functions** of the inputs —
//!   a hit must return bit-identical values to a recompute (the
//!   per-worker training-graph and stage-cuts memos obey this);
//! * the cost cache, except through the passed handle — and only for
//!   values that are themselves pure (the `eval` contract).
//!
//! Anything else breaks the engine's core guarantee, pinned by
//! `tests/dse_engine.rs`: **rows are bit-identical across any worker
//! count and any cache setting** (off / cold / warm-persisted /
//! capacity-bounded).

use std::collections::HashMap;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use super::journal::{self, JournalRow, PointRecord};
use super::space::{ClusterPoint, DesignPoint};
use crate::eval::{persist, CacheStats, CostCache};
use crate::parallelism::{HeteroCluster, HeteroPoint};

/// A finite, deterministically ordered set of evaluable design points
/// with stable per-point ids. See the module docs for the contract.
pub trait DesignSpace {
    type Point: Sync;

    /// The points, in the space's canonical (deterministic) order.
    fn points(&self) -> &[Self::Point];

    /// Stable, unique-within-the-space id of the `index`-th point — the
    /// same string the family's [`Evaluate`] impl emits as the row label
    /// (golden tests and CSVs key on it). Uniqueness is enforced in
    /// debug builds by [`Engine::run`], which is what keeps a space's
    /// ids and its evaluator's labels from drifting apart silently.
    fn point_id(&self, index: usize) -> String;

    fn len(&self) -> usize {
        self.points().len()
    }

    fn is_empty(&self) -> bool {
        self.points().is_empty()
    }
}

/// The single-device accelerator space: a slice of [`DesignPoint`]s in
/// enumeration order, identified by their sweep labels.
impl DesignSpace for [DesignPoint] {
    type Point = DesignPoint;

    fn points(&self) -> &[DesignPoint] {
        self
    }

    fn point_id(&self, index: usize) -> String {
        self[index].label()
    }
}

/// The homogeneous deployment space: a slice of [`ClusterPoint`]s in
/// enumeration order, identified by their row labels.
impl DesignSpace for [ClusterPoint] {
    type Point = ClusterPoint;

    fn points(&self) -> &[ClusterPoint] {
        self
    }

    fn point_id(&self, index: usize) -> String {
        self[index].label()
    }
}

/// The heterogeneous stage-placement space: enumerated [`HeteroPoint`]s
/// plus the device pool they are placed on (a point's label needs the
/// pool's class names, so a bare slice cannot implement [`DesignSpace`]).
pub struct HeteroSpace<'a> {
    pub points: &'a [HeteroPoint],
    pub cluster: &'a HeteroCluster,
}

impl DesignSpace for HeteroSpace<'_> {
    type Point = HeteroPoint;

    fn points(&self) -> &[HeteroPoint] {
        self.points
    }

    fn point_id(&self, index: usize) -> String {
        self.points[index].label(self.cluster)
    }
}

/// The minimized objective set every MONET experiment reports — the
/// typed replacement for the ad-hoc `Vec<f64>` rows the sweeps used to
/// hand to the NSGA-II ranking. Single-device rows report `devices = 1`;
/// cluster rows report per-device memory and the cluster size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub memory_bytes: u64,
    pub devices: usize,
}

impl Objectives {
    /// The flat minimized vector `ga::nsga2::pareto_rank0` consumes, in
    /// the canonical order (latency, energy, memory, devices).
    pub fn to_vec(self) -> Vec<f64> {
        vec![
            self.latency_cycles,
            self.energy_pj,
            self.memory_bytes as f64,
            self.devices as f64,
        ]
    }
}

/// How one design point becomes result rows. One instance serves the
/// whole pool (`&self` from every worker); per-worker mutable state
/// lives in [`Evaluate::Scratch`]. See the module docs for what an
/// implementation may NOT read.
pub trait Evaluate: Sync {
    type Point: Sync;
    /// One result row; a point may emit several (e.g. one per mode).
    type Row: Send;
    /// Per-worker scratch: memos of pure functions only (training-graph
    /// memo, stage-cuts memo). Created once per worker, never shared
    /// concurrently — the pruned path hands idle scratches to later
    /// workers through a pool (hence `Send`), which is sound because a
    /// memo hit must be bit-identical to a recompute.
    type Scratch: Send;

    /// Fresh scratch for one worker.
    fn scratch(&self) -> Self::Scratch;

    /// Evaluate the `index`-th point into rows. `cache` is the
    /// engine-owned shared cost cache (`None` under `--no-cache`).
    fn evaluate(
        &self,
        index: usize,
        point: &Self::Point,
        cache: Option<&CostCache>,
        scratch: &mut Self::Scratch,
    ) -> Vec<Self::Row>;

    /// Cheap **admissible lower bounds** on the rows this point would
    /// produce, or `None` for "no bound" (the conservative default: the
    /// point always evaluates).
    ///
    /// # The admissibility contract (what makes pruning sound)
    ///
    /// The returned vectors and [`Evaluate::row_objectives`] must agree
    /// on one minimized objective geometry (same length, same component
    /// meaning), and for **every** row `r` the point's evaluation would
    /// emit, some returned bound `b` must satisfy
    /// `b[k] <= row_objectives(r)[k]` for every component `k` — a bound
    /// may be arbitrarily loose, but must **never** exceed the true
    /// value in any component. Under that contract, an already-evaluated
    /// row that Pareto-dominates every bound of a point strictly
    /// dominates every row the point would produce, so skipping the
    /// point can remove only dominated rows and the rank-0 front is
    /// bit-identical with pruning on or off (ties are not dominance:
    /// a point whose true objectives merely equal an incumbent's is
    /// never skipped through a bound `<=` its truth).
    ///
    /// Like [`Evaluate::evaluate`], the bound must be a pure function of
    /// `(index, point, &self)` (the scratch only as a memo of pure
    /// functions), and it must not read or write the cost cache —
    /// pruning must not change what gets cached for surviving points.
    /// Return one bound per prospective row (or any covering set); an
    /// empty set is treated as "no bound".
    fn lower_bound(
        &self,
        _index: usize,
        _point: &Self::Point,
        _scratch: &mut Self::Scratch,
    ) -> Option<Vec<Vec<f64>>> {
        None
    }

    /// The minimized objective vector of one emitted row, in the same
    /// geometry as [`Evaluate::lower_bound`], or `None` for "this family
    /// does not participate in pruning". Both hooks must be implemented
    /// (and agree) for the engine to prune.
    fn row_objectives(&self, _row: &Self::Row) -> Option<Vec<f64>> {
        None
    }
}

/// One design point whose evaluation panicked: the engine's per-point
/// isolation caught it, recorded the diagnostic, and completed the rest
/// of the sweep. Surfaced in [`RunOutcome::failures`] (and journaled, so
/// a resumed run neither re-evaluates nor forgets the point).
#[derive(Debug, Clone, PartialEq)]
pub struct PointFailure {
    /// Index of the point in its [`DesignSpace`].
    pub index: usize,
    /// The space's stable id of the point ([`DesignSpace::point_id`]).
    pub point_id: String,
    /// The panic payload (or `"non-string panic payload"`).
    pub diagnostic: String,
}

/// Everything one engine run produced: the rows of every successful
/// point (index-ordered), the shared cache's end-of-run counters
/// (including the snapshot-lifecycle events), the isolated per-point
/// failures, and how many points were replayed from a resumed journal
/// rather than evaluated.
#[derive(Debug, Clone)]
pub struct RunOutcome<R> {
    pub rows: Vec<R>,
    pub cache: CacheStats,
    pub failures: Vec<PointFailure>,
    pub resumed: usize,
    /// Indices of points the bound-based pruner skipped without
    /// evaluating (sorted; includes skips replayed from a resumed
    /// journal). Every skipped point's rows are Pareto-dominated by a
    /// returned row, so fronts are unaffected.
    pub skipped: Vec<usize>,
}

impl<R> RunOutcome<R> {
    /// Did every point evaluate cleanly?
    pub fn is_clean(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Structural failure of the engine or the parallel map itself (as
/// opposed to isolated per-point failures, which are data in
/// [`RunOutcome::failures`]). Implements `std::error::Error`, so it
/// converts into [`crate::util::error::Error`] via `?`.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// The worker pool delivered no result for these indices (sorted) —
    /// a harness bug, never an input problem.
    MissingIndices(Vec<usize>),
    /// [`try_map_parallel`] items whose closure panicked, with their
    /// diagnostics (sorted by index).
    Poisoned(Vec<(usize, String)>),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::MissingIndices(idx) => {
                write!(
                    f,
                    "worker pool delivered no result for {} item(s): indices {:?}",
                    idx.len(),
                    idx
                )
            }
            EngineError::Poisoned(items) => {
                write!(f, "{} item(s) panicked during parallel evaluation:", items.len())?;
                for (i, diag) in items.iter().take(5) {
                    write!(f, " [{i}: {diag}]")?;
                }
                if items.len() > 5 {
                    write!(f, " (+{} more)", items.len() - 5)?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// A caller-owned resident [`CostCache`] handle, for embedding the
/// engine in a long-lived process (`monet serve`): the engine uses the
/// shared cache instead of opening its own, and — crucially — does
/// **not** persist it at end-of-run. The owner controls the snapshot
/// lifecycle (the daemon persists at its single shutdown/checkpoint
/// point), so concurrent queries never race on the snapshot file.
///
/// Cached values are pure functions of the key, so sharing one cache
/// across concurrent runs cannot change any row — warm-daemon results
/// stay bit-identical to cold one-shot runs (pinned in
/// `tests/serve.rs`).
#[derive(Clone)]
pub struct SharedCache(pub Arc<CostCache>);

impl fmt::Debug for SharedCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SharedCache({} entries)", self.0.stats().entries)
    }
}

/// The engine's orchestration knobs: worker count plus the shared
/// cost-cache lifecycle (the CLI's `--no-cache` / `--cache-dir` /
/// `--cache-cap` triple — one definition, so the semantics cannot drift
/// across commands) plus the crash-safety pair (`--run-dir` /
/// `--resume`).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (1 = serial). Results are bit-identical for every
    /// value — parallelism only changes wall-clock.
    pub workers: usize,
    /// Share one [`CostCache`] across the pool. `false` (the
    /// `--no-cache` escape hatch) recomputes every group cost and
    /// **wins over `cache_dir`**: nothing is loaded or saved.
    pub use_cache: bool,
    /// Persist the cost cache across process runs (`--cache-dir`):
    /// warm-load the snapshot before the run, write it back after.
    /// Stale/incompatible snapshots are rejected wholesale
    /// (see [`crate::eval::persist`]).
    pub cache_dir: Option<PathBuf>,
    /// Bound the cache to ~this many entries with the sharded CLOCK
    /// policy (`--cache-cap`); 0 = unbounded.
    pub cache_cap: usize,
    /// Journal every completed point to this directory (`--run-dir`),
    /// making the run resumable after a crash. `None` (the default)
    /// journals nothing. Only honored by [`Engine::run_journaled`] —
    /// plain [`Engine::run`] serves row types with no journal codec.
    pub run_dir: Option<PathBuf>,
    /// Replay a `run_dir` journal left by a previous (killed) run before
    /// evaluating (`--resume`): completed points are restored from the
    /// journal, bit-identically, and only the remainder is evaluated.
    pub resume: bool,
    /// Use this caller-owned resident cache instead of opening one
    /// (`monet serve`'s warm cache). When set (and `use_cache` is on),
    /// the engine neither warm-loads a `cache_dir` snapshot nor
    /// persists one at end-of-run — the cache's owner controls the
    /// snapshot lifecycle. Ignored when `use_cache` is off.
    pub shared_cache: Option<SharedCache>,
    /// Skip points whose [`Evaluate::lower_bound`] cannot beat the
    /// incumbent front (`true`, the default; `--no-prune` turns it
    /// off). Only engages for evaluators that implement both pruning
    /// hooks — the rank-0 Pareto front is bit-identical either way
    /// (pinned by `tests/front_equivalence.rs`), only dominated rows
    /// may be elided.
    pub prune: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            use_cache: true,
            cache_dir: None,
            cache_cap: 0,
            run_dir: None,
            resume: false,
            shared_cache: None,
            prune: true,
        }
    }
}

/// The generic sweep/search harness. See the module docs.
pub struct Engine {
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        Engine { cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Evaluate every point of `space` over the worker pool.
    ///
    /// Guarantees (pinned by `tests/dse_engine.rs` and
    /// `tests/fault_injection.rs`):
    ///
    /// * **ordering** — [`RunOutcome::rows`] come back sorted by point
    ///   index; a point's own rows keep their emission order;
    /// * **determinism** — bit-identical rows for any `workers` value
    ///   and any cache setting (off / cold / warm / bounded);
    /// * **lifecycle** — with `use_cache`, the cache is opened (warm-
    ///   loading a `cache_dir` snapshot when present, bounded by
    ///   `cache_cap`) before evaluation and persisted back after; with
    ///   `use_cache` off nothing is loaded, counted or saved;
    /// * **progress** — `progress(done, total)` fires once per completed
    ///   point, in completion order (a pruned-away point counts as
    ///   completed the moment it is skipped);
    /// * **pruning** — with `cfg.prune` (the default) and an evaluator
    ///   implementing [`Evaluate::lower_bound`] +
    ///   [`Evaluate::row_objectives`], points whose bound is Pareto-
    ///   dominated by an already-produced row are skipped without
    ///   evaluation ([`RunOutcome::skipped`]). The skip set is itself
    ///   deterministic (bound-sorted order, fixed-size chunks, incumbent
    ///   grown only at chunk barriers), and by the admissibility
    ///   contract only dominated rows can be elided — the rank-0 front
    ///   is bit-identical to a `--no-prune` run.
    ///
    /// # Failure semantics
    ///
    /// Three disjoint failure classes, three disjoint channels:
    ///
    /// * **A panicking point** is *isolated*: the evaluation runs inside
    ///   a `catch_unwind` fence, the panic becomes a
    ///   [`PointFailure`] in [`RunOutcome::failures`], and every other
    ///   point still evaluates. The run returns `Ok`; callers decide
    ///   whether a degraded sweep is acceptable (the CLI reports each
    ///   failure and exits nonzero).
    /// * **Cache-lifecycle trouble** (rejected snapshot, failed
    ///   persist) *degrades gracefully*: warnings plus the
    ///   `snapshots_rejected` / `snapshots_quarantined` / `io_retries`
    ///   counters in [`RunOutcome::cache`] — never a panic, never
    ///   silence, never a changed row.
    /// * **A harness defect** (the pool failing to deliver an index) is
    ///   the only `Err`: [`EngineError::MissingIndices`].
    pub fn run<S, E>(
        &self,
        space: &S,
        eval: &E,
        progress: impl FnMut(usize, usize),
    ) -> Result<RunOutcome<E::Row>, EngineError>
    where
        S: DesignSpace + ?Sized,
        E: Evaluate<Point = S::Point>,
    {
        self.run_core(space, eval, progress, HashMap::new(), |_, _| {})
    }

    /// [`Engine::run`] plus the crash-safety layer, for row types with a
    /// journal codec ([`JournalRow`]).
    ///
    /// With `run_dir` set, every completed point (rows *or* isolated
    /// failure) is appended to the checksummed run journal before the
    /// next progress tick; with `resume` also set, a journal left by a
    /// previous run of the **same design space** (same
    /// [`journal::space_digest`]) is replayed first — torn tails are
    /// truncated to the last good record — and only the remaining points
    /// evaluate. A resumed run's [`RunOutcome::rows`] are bit-identical
    /// to an uninterrupted run's.
    ///
    /// # Failure semantics
    ///
    /// Everything on [`Engine::run`] holds, plus: a journal that cannot
    /// be opened (unwritable `run_dir`, disk full) or appended to
    /// degrades with a warning to an unjournaled run — journaling
    /// trouble never fails a sweep, and never changes a row. A resume
    /// against a journal from a different space/config quarantines the
    /// file and starts fresh.
    pub fn run_journaled<S, E>(
        &self,
        space: &S,
        eval: &E,
        progress: impl FnMut(usize, usize),
    ) -> Result<RunOutcome<E::Row>, EngineError>
    where
        S: DesignSpace + ?Sized,
        E: Evaluate<Point = S::Point>,
        E::Row: JournalRow,
    {
        let Some(run_dir) = self.cfg.run_dir.clone() else {
            return self.run_core(space, eval, progress, HashMap::new(), |_, _| {});
        };
        let digest = journal::space_digest(space);
        let path = run_dir.join(journal::RUN_JOURNAL_FILE);
        let (payloads, file) =
            match journal::open_journal(&path, journal::RUN_MAGIC, digest, self.cfg.resume) {
                Ok(x) => x,
                Err(e) => {
                    eprintln!(
                        "warning: cannot open run journal {} ({e}); \
                         continuing without crash-safety",
                        path.display()
                    );
                    return self.run_core(space, eval, progress, HashMap::new(), |_, _| {});
                }
            };
        let n = space.len();
        let mut replay: HashMap<usize, PointRecord<E::Row>> = HashMap::new();
        for payload in &payloads {
            if let Some((i, rec)) = journal::decode_point_record::<E::Row>(payload) {
                if i < n {
                    replay.insert(i, rec);
                }
            }
        }
        let mut file = file;
        let mut dead = false;
        self.run_core(space, eval, progress, replay, move |i, rec| {
            if dead {
                return;
            }
            let payload = journal::encode_point_record(i, rec);
            if let Err(e) = file.append_record(&payload) {
                eprintln!(
                    "warning: run journal write failed ({e}); \
                     continuing without crash-safety"
                );
                dead = true;
            }
        })
    }

    /// The shared core: pool + cache lifecycle + panic isolation, with
    /// `replay` pre-filling completed points and `on_complete` observing
    /// each newly completed one (on the calling thread, in completion
    /// order — the journal append needs no locks).
    fn run_core<S, E>(
        &self,
        space: &S,
        eval: &E,
        mut progress: impl FnMut(usize, usize),
        replay: HashMap<usize, PointRecord<E::Row>>,
        mut on_complete: impl FnMut(usize, &PointRecord<E::Row>),
    ) -> Result<RunOutcome<E::Row>, EngineError>
    where
        S: DesignSpace + ?Sized,
        E: Evaluate<Point = S::Point>,
    {
        let points = space.points();
        let n = points.len();
        #[cfg(debug_assertions)]
        {
            // the DesignSpace id contract: unique within the space
            let mut seen = std::collections::HashSet::with_capacity(n);
            for i in 0..n {
                let id = space.point_id(i);
                assert!(seen.insert(id.clone()), "DesignSpace ids must be unique: {id:?}");
            }
        }
        // Three cache modes: off (`--no-cache`), engine-owned (open a
        // fresh/persisted cache for this run, persist it after), or
        // caller-owned (`shared_cache` — a resident daemon's warm cache;
        // the engine must not persist it, the owner does).
        let owned_cache = if self.cfg.use_cache && self.cfg.shared_cache.is_none() {
            Some(persist::open_cost_cache(self.cfg.cache_dir.as_deref(), self.cfg.cache_cap))
        } else {
            None
        };
        let cache_ref: Option<&CostCache> = if !self.cfg.use_cache {
            None
        } else if let Some(shared) = &self.cfg.shared_cache {
            Some(&shared.0)
        } else {
            owned_cache.as_ref()
        };

        let mut slots: Vec<Option<PointRecord<E::Row>>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        let resumed = replay.len();
        // audit:allow(DT02): each entry writes its own `slots[i]` — disjoint indexed stores commute
        for (i, rec) in replay {
            slots[i] = Some(rec);
        }
        let pending: Vec<usize> =
            (0..n).filter(|&i| slots[i].is_none()).collect();
        let mut done = resumed;
        if resumed > 0 {
            progress(done, n);
        }

        // Bound pass (ROADMAP item 5): with pruning on, ask the evaluator
        // for an admissible lower bound per pending point — serially, on
        // one dedicated scratch that later seeds the worker pool. Bounds
        // never touch the cost cache, so what gets cached for surviving
        // points is byte-identical to a `--no-prune` run.
        let mut bounds: HashMap<usize, Vec<Vec<f64>>> = HashMap::new();
        let mut seed_scratch: Vec<E::Scratch> = Vec::new();
        if self.cfg.prune && !pending.is_empty() {
            let mut sc = eval.scratch();
            for &i in &pending {
                if let Some(bs) = eval.lower_bound(i, &points[i], &mut sc) {
                    if !bs.is_empty() {
                        bounds.insert(i, bs);
                    }
                }
            }
            seed_scratch.push(sc);
        }

        if bounds.is_empty() {
            // the exhaustive path: pruning off, or a family with no bound
            run_pool(
                self.cfg.workers,
                pending.len(),
                &|| eval.scratch(),
                &|j, scratch: &mut E::Scratch| {
                    let i = pending[j];
                    // AssertUnwindSafe: a panicking evaluation may only have
                    // touched its own per-worker scratch (dropped with the
                    // worker) and the cost cache outside its locks (compute
                    // happens unlocked; see CostCache::get_or_compute), so no
                    // shared state observable by other points is left torn.
                    match catch_unwind(AssertUnwindSafe(|| {
                        crate::util::fault::panic_point(i);
                        eval.evaluate(i, &points[i], cache_ref, scratch)
                    })) {
                        Ok(rows) => PointRecord::Rows(rows),
                        Err(payload) => PointRecord::Failed(panic_message(payload)),
                    }
                },
                |j, rec| {
                    let i = pending[j];
                    on_complete(i, &rec);
                    slots[i] = Some(rec);
                    done += 1;
                    progress(done, n);
                },
            );
        } else {
            // The pruned path. Every skip decision is a pure function of
            // the space (never of worker timing): points are processed in
            // a deterministic bound-sorted order, in fixed-size chunks,
            // and the incumbent row set only grows at chunk barriers — so
            // the set of skipped points is bit-identical across worker
            // counts and cache settings.
            let mut incumbent: Vec<Vec<f64>> = Vec::new();
            for slot in slots.iter().flatten() {
                if let PointRecord::Rows(rows) = slot {
                    for row in rows {
                        if let Some(o) = eval.row_objectives(row) {
                            incumbent.push(o);
                        }
                    }
                }
            }
            // promising (small-bound) points first, so the incumbent
            // front gets strong early and later chunks skip hard;
            // unbounded points (never skippable) go first of all
            let mut order = pending.clone();
            order.sort_by(|&a, &b| match (bounds.get(&a), bounds.get(&b)) {
                (Some(x), Some(y)) => bound_order(x, y).then(a.cmp(&b)),
                (None, Some(_)) => std::cmp::Ordering::Less,
                (Some(_), None) => std::cmp::Ordering::Greater,
                (None, None) => a.cmp(&b),
            });
            let pool = std::sync::Mutex::new(seed_scratch);
            for chunk in order.chunks(PRUNE_CHUNK) {
                let mut to_run: Vec<usize> = Vec::with_capacity(chunk.len());
                for &i in chunk {
                    let skip = bounds.get(&i).is_some_and(|bs| {
                        bs.iter().all(|b| incumbent.iter().any(|r| dominates(r, b)))
                    });
                    if skip {
                        let rec = PointRecord::Skipped;
                        on_complete(i, &rec);
                        slots[i] = Some(rec);
                        done += 1;
                        progress(done, n);
                    } else {
                        to_run.push(i);
                    }
                }
                run_pool(
                    self.cfg.workers,
                    to_run.len(),
                    &|| PooledScratch::checkout(&pool, || eval.scratch()),
                    &|j, scratch: &mut PooledScratch<'_, E::Scratch>| {
                        let i = to_run[j];
                        // AssertUnwindSafe: as on the exhaustive path
                        match catch_unwind(AssertUnwindSafe(|| {
                            crate::util::fault::panic_point(i);
                            eval.evaluate(i, &points[i], cache_ref, scratch.get())
                        })) {
                            Ok(rows) => PointRecord::Rows(rows),
                            Err(payload) => PointRecord::Failed(panic_message(payload)),
                        }
                    },
                    |j, rec| {
                        let i = to_run[j];
                        on_complete(i, &rec);
                        slots[i] = Some(rec);
                        done += 1;
                        progress(done, n);
                    },
                );
                // chunk barrier: fold the chunk's rows into the incumbent
                for &i in &to_run {
                    if let Some(PointRecord::Rows(rows)) = &slots[i] {
                        for row in rows {
                            if let Some(o) = eval.row_objectives(row) {
                                incumbent.push(o);
                            }
                        }
                    }
                }
            }
        }

        // satellite of the robustness PR: a structured error instead of
        // the old `expect("pool delivered every index")`
        let missing: Vec<usize> =
            (0..n).filter(|&i| slots[i].is_none()).collect();
        if !missing.is_empty() {
            return Err(EngineError::MissingIndices(missing));
        }

        // persist BEFORE snapshotting the counters, so retried-write
        // events (CacheStats::io_retries) reach the end-of-run report;
        // only the engine-owned cache is persisted — a shared cache's
        // owner holds the single persist point
        if let Some(c) = &owned_cache {
            persist::persist_cost_cache(c, self.cfg.cache_dir.as_deref());
        }
        let stats = cache_ref.map(|c| c.stats()).unwrap_or_default();

        let mut rows = Vec::new();
        let mut failures = Vec::new();
        let mut skipped = Vec::new();
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(PointRecord::Rows(r)) => rows.extend(r),
                Some(PointRecord::Failed(diagnostic)) => failures.push(PointFailure {
                    index: i,
                    point_id: space.point_id(i),
                    diagnostic,
                }),
                Some(PointRecord::Skipped) => skipped.push(i),
                None => unreachable!("missing indices returned above"),
            }
        }
        Ok(RunOutcome { rows, cache: stats, failures, resumed, skipped })
    }
}

/// Points per pruning chunk: skip decisions are made for a whole chunk
/// against the incumbent front, the chunk evaluates over the pool, and
/// the barrier folds its rows in. A constant (never derived from the
/// worker count) so the skipped set is identical for any `workers`.
const PRUNE_CHUNK: usize = 8;

/// `a` Pareto-dominates `b` (both minimized): `<=` in every component,
/// `<` in at least one. Length mismatches and NaNs compare as
/// non-dominating — an uncomparable pair must never justify a skip.
fn dominates(a: &[f64], b: &[f64]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if !(x <= y) {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// Deterministic total order over bound sets: lexicographic over the
/// flattened components (`total_cmp`), then by total length. Pure
/// tie-breaking structure — any total order keeps pruning sound; this
/// one fronts points with small bounds.
fn bound_order(a: &[Vec<f64>], b: &[Vec<f64>]) -> std::cmp::Ordering {
    for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
        match x.total_cmp(y) {
            std::cmp::Ordering::Equal => {}
            o => return o,
        }
    }
    let la: usize = a.iter().map(Vec::len).sum();
    let lb: usize = b.iter().map(Vec::len).sum();
    la.cmp(&lb)
}

/// A worker scratch checked out of a shared pool and returned on drop,
/// so per-worker memos survive across the pruned path's chunk barriers
/// (each chunk spawns a fresh pool). Sound because scratches are memos
/// of pure functions: a warm checkout returns bit-identical rows to a
/// cold one.
struct PooledScratch<'p, S> {
    slot: Option<S>,
    pool: &'p std::sync::Mutex<Vec<S>>,
}

impl<'p, S> PooledScratch<'p, S> {
    fn checkout(pool: &'p std::sync::Mutex<Vec<S>>, fresh: impl FnOnce() -> S) -> Self {
        let warm = pool.lock().ok().and_then(|mut p| p.pop());
        PooledScratch { slot: Some(warm.unwrap_or_else(fresh)), pool }
    }

    fn get(&mut self) -> &mut S {
        self.slot.as_mut().expect("scratch present until drop")
    }
}

impl<S> Drop for PooledScratch<'_, S> {
    fn drop(&mut self) {
        if let Some(s) = self.slot.take() {
            if let Ok(mut p) = self.pool.lock() {
                p.push(s);
            }
        }
    }
}

/// Render a caught panic payload for diagnostics.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Deterministic parallel map over a slice: `out[i] == f(&items[i])`
/// for every `i`, regardless of `workers`. This is the engine's pool
/// exposed for callers that own their own caching (the NSGA-II GA's
/// per-generation genome batches); `f` must be pure. Panics on any
/// [`EngineError`] — callers that need the structured error (which item
/// panicked, with what diagnostic) use [`try_map_parallel`].
pub fn map_parallel<T, R>(
    workers: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R>
where
    T: Sync,
    R: Send,
{
    try_map_parallel(workers, items, f)
        .unwrap_or_else(|e| panic!("parallel map failed: {e}"))
}

/// [`map_parallel`] with structured failure: a panicking item does not
/// abort the process — every item still runs, and the collected
/// diagnostics come back as [`EngineError::Poisoned`] (sorted by index).
pub fn try_map_parallel<T, R>(
    workers: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Result<Vec<R>, EngineError>
where
    T: Sync,
    R: Send,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let mut poisoned: Vec<(usize, String)> = Vec::new();
    run_pool(
        workers,
        n,
        &|| (),
        &|i, _scratch: &mut ()| {
            catch_unwind(AssertUnwindSafe(|| f(&items[i]))).map_err(panic_message)
        },
        |i, r| match r {
            Ok(v) => out[i] = Some(v),
            Err(diag) => poisoned.push((i, diag)),
        },
    );
    if !poisoned.is_empty() {
        poisoned.sort_by(|a, b| a.0.cmp(&b.0));
        return Err(EngineError::Poisoned(poisoned));
    }
    let mut missing = Vec::new();
    let mut vals = Vec::with_capacity(n);
    for (i, slot) in out.into_iter().enumerate() {
        match slot {
            Some(v) => vals.push(v),
            None => missing.push(i),
        }
    }
    if !missing.is_empty() {
        return Err(EngineError::MissingIndices(missing));
    }
    Ok(vals)
}

/// The one worker-pool core every harness shares: a work-stealing index
/// over scoped threads, one `scratch()` per worker, results streamed
/// back to the caller's thread as `(index, result)` via `sink` (in
/// completion order — callers needing index order sort or slot by `i`).
/// Serial (no threads spawned) when one worker suffices.
fn run_pool<R, Sc>(
    workers: usize,
    n: usize,
    scratch: &(impl Fn() -> Sc + Sync),
    task: &(impl Fn(usize, &mut Sc) -> R + Sync),
    mut sink: impl FnMut(usize, R),
) where
    R: Send,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        let mut sc = scratch();
        for i in 0..n {
            sink(i, task(i, &mut sc));
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || {
                let mut sc = scratch();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if tx.send((i, task(i, &mut sc))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        while let Ok((i, r)) = rx.recv() {
            sink(i, r);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::persist::Reader;

    /// A synthetic space: points are integers, ids are their decimal
    /// strings.
    struct IntSpace(Vec<u64>);

    impl DesignSpace for IntSpace {
        type Point = u64;

        fn points(&self) -> &[u64] {
            &self.0
        }

        fn point_id(&self, index: usize) -> String {
            format!("int{}", self.0[index])
        }
    }

    /// Squares each point; the scratch counts this worker's evaluations
    /// (a memo-shaped use: it never alters results).
    struct SquareEval;

    impl Evaluate for SquareEval {
        type Point = u64;
        type Row = (usize, u64);
        type Scratch = usize;

        fn scratch(&self) -> usize {
            0
        }

        fn evaluate(
            &self,
            index: usize,
            point: &u64,
            _cache: Option<&CostCache>,
            scratch: &mut usize,
        ) -> Vec<(usize, u64)> {
            *scratch += 1;
            vec![(index, point * point)]
        }
    }

    impl JournalRow for (usize, u64) {
        fn encode(&self, buf: &mut Vec<u8>) {
            crate::eval::persist::put_u64(buf, self.0 as u64);
            crate::eval::persist::put_u64(buf, self.1);
        }
        fn decode(r: &mut Reader<'_>) -> Option<(usize, u64)> {
            Some((r.u64()? as usize, r.u64()?))
        }
    }

    /// Panics on one configured point, squares the rest.
    struct PanickyEval(u64);

    impl Evaluate for PanickyEval {
        type Point = u64;
        type Row = (usize, u64);
        type Scratch = ();

        fn scratch(&self) {}

        fn evaluate(
            &self,
            index: usize,
            point: &u64,
            _cache: Option<&CostCache>,
            _scratch: &mut (),
        ) -> Vec<(usize, u64)> {
            assert!(*point != self.0, "poisoned point {point}");
            vec![(index, point * point)]
        }
    }

    fn no_cache_cfg(workers: usize) -> EngineConfig {
        EngineConfig { workers, use_cache: false, ..Default::default() }
    }

    #[test]
    fn rows_are_index_ordered_and_identical_across_worker_counts() {
        let space = IntSpace((0..97).map(|i| i * 3 + 1).collect());
        let run = |workers: usize| {
            let mut calls = 0usize;
            let out = Engine::new(no_cache_cfg(workers))
                .run(&space, &SquareEval, |_, _| calls += 1)
                .unwrap();
            assert_eq!(calls, space.len());
            assert_eq!(out.cache, CacheStats::default());
            assert!(out.is_clean());
            assert_eq!(out.resumed, 0);
            out.rows
        };
        let serial = run(1);
        assert_eq!(serial.len(), 97);
        for (i, &(idx, sq)) in serial.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(sq, space.0[i] * space.0[i]);
        }
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(8));
        assert_eq!(serial, run(64), "more workers than points must still work");
    }

    #[test]
    fn multi_row_points_keep_emission_order() {
        struct PairEval;
        impl Evaluate for PairEval {
            type Point = u64;
            type Row = (usize, &'static str);
            type Scratch = ();
            fn scratch(&self) {}
            fn evaluate(
                &self,
                index: usize,
                _point: &u64,
                _cache: Option<&CostCache>,
                _scratch: &mut (),
            ) -> Vec<(usize, &'static str)> {
                vec![(index, "first"), (index, "second")]
            }
        }
        let space = IntSpace((0..13).collect());
        let out = Engine::new(no_cache_cfg(4)).run(&space, &PairEval, |_, _| {}).unwrap();
        assert_eq!(out.rows.len(), 26);
        for (i, pair) in out.rows.chunks(2).enumerate() {
            assert_eq!(pair[0], (i, "first"));
            assert_eq!(pair[1], (i, "second"));
        }
    }

    #[test]
    fn empty_space_yields_no_rows_and_no_progress() {
        let space = IntSpace(vec![]);
        let mut calls = 0usize;
        let out =
            Engine::new(no_cache_cfg(4)).run(&space, &SquareEval, |_, _| calls += 1).unwrap();
        assert!(out.rows.is_empty());
        assert_eq!(calls, 0);
        assert_eq!(out.cache, CacheStats::default());
    }

    #[test]
    fn a_panicking_point_is_isolated_not_fatal() {
        let space = IntSpace((0..20).collect());
        for workers in [1usize, 2, 8] {
            let out = Engine::new(no_cache_cfg(workers))
                .run(&space, &PanickyEval(7), |_, _| {})
                .unwrap();
            assert_eq!(out.rows.len(), 19, "every healthy point must evaluate");
            assert_eq!(out.failures.len(), 1);
            let f = &out.failures[0];
            assert_eq!(f.index, 7);
            assert_eq!(f.point_id, "int7");
            assert!(f.diagnostic.contains("poisoned point 7"), "{:?}", f.diagnostic);
            assert!(!out.rows.iter().any(|&(i, _)| i == 7));
        }
    }

    #[test]
    fn journaled_run_resumes_bit_identically_without_reevaluating() {
        let dir = std::env::temp_dir()
            .join(format!("monet_engine_journal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let space = IntSpace((0..31).map(|i| i + 100).collect());
        let cfg = EngineConfig {
            workers: 2,
            use_cache: false,
            run_dir: Some(dir.clone()),
            ..Default::default()
        };
        let full = Engine::new(cfg.clone()).run_journaled(&space, &SquareEval, |_, _| {}).unwrap();
        assert_eq!(full.resumed, 0);

        /// Refuses to evaluate anything — a resume of a complete journal
        /// must replay every point.
        struct MustNotRun;
        impl Evaluate for MustNotRun {
            type Point = u64;
            type Row = (usize, u64);
            type Scratch = ();
            fn scratch(&self) {}
            fn evaluate(
                &self,
                _i: usize,
                _p: &u64,
                _c: Option<&CostCache>,
                _s: &mut (),
            ) -> Vec<(usize, u64)> {
                panic!("resume of a complete journal re-evaluated a point")
            }
        }
        let resumed = Engine::new(EngineConfig { resume: true, ..cfg })
            .run_journaled(&space, &MustNotRun, |_, _| {})
            .unwrap();
        assert_eq!(resumed.resumed, space.len());
        assert!(resumed.is_clean(), "{:?}", resumed.failures);
        assert_eq!(resumed.rows, full.rows);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn map_parallel_matches_serial_map_for_any_worker_count() {
        let items: Vec<u64> = (0..61).map(|i| i * 7).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for workers in [1usize, 2, 3, 8, 100] {
            assert_eq!(map_parallel(workers, &items, |x| x * x + 1), expect);
        }
        let empty: Vec<u64> = vec![];
        assert!(map_parallel(4, &empty, |x| *x).is_empty());
    }

    #[test]
    fn try_map_parallel_names_every_poisoned_item() {
        let items: Vec<u64> = (0..16).collect();
        for workers in [1usize, 4] {
            let err = try_map_parallel(workers, &items, |&x| {
                assert!(x != 3 && x != 11, "bad item {x}");
                x * 2
            })
            .unwrap_err();
            match err {
                EngineError::Poisoned(items) => {
                    let idx: Vec<usize> = items.iter().map(|p| p.0).collect();
                    assert_eq!(idx, vec![3, 11]);
                    assert!(items[0].1.contains("bad item 3"));
                }
                other => panic!("expected Poisoned, got {other:?}"),
            }
        }
        assert!(try_map_parallel(2, &items, |&x| x).is_ok());
    }

    #[test]
    fn engine_error_displays_are_actionable() {
        let e = EngineError::MissingIndices(vec![3, 9]);
        assert!(e.to_string().contains("[3, 9]"), "{e}");
        let p = EngineError::Poisoned(vec![(5, "kaboom".into())]);
        let s = p.to_string();
        assert!(s.contains('5') && s.contains("kaboom"), "{s}");
        // EngineError converts into the repo-wide error type via `?`
        fn fails() -> crate::util::error::Result<()> {
            Err(EngineError::MissingIndices(vec![1]))?;
            Ok(())
        }
        assert!(fails().is_err());
    }

    #[test]
    fn hetero_space_ids_come_from_the_pool() {
        use crate::parallelism::DeviceClass;
        let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2)]);
        let points = vec![HeteroPoint {
            dp: 1,
            pp: 2,
            microbatches: 2,
            tp: 1,
            placement: vec![0, 0],
        }];
        let space = HeteroSpace { points: &points, cluster: &hc };
        assert_eq!(space.len(), 1);
        assert_eq!(space.point_id(0), points[0].label(&hc));
    }

    /// Identity objectives with an exact lower bound: evaluation emits
    /// the point's value as its single minimized objective, and the
    /// bound equals the truth — the sharpest admissible bound there is.
    struct BoundedEval;

    impl Evaluate for BoundedEval {
        type Point = u64;
        type Row = (usize, u64);
        type Scratch = ();

        fn scratch(&self) {}

        fn evaluate(
            &self,
            index: usize,
            point: &u64,
            _cache: Option<&CostCache>,
            _scratch: &mut (),
        ) -> Vec<(usize, u64)> {
            vec![(index, *point)]
        }

        fn lower_bound(
            &self,
            _index: usize,
            point: &u64,
            _scratch: &mut (),
        ) -> Option<Vec<Vec<f64>>> {
            Some(vec![vec![*point as f64]])
        }

        fn row_objectives(&self, row: &(usize, u64)) -> Option<Vec<f64>> {
            Some(vec![row.1 as f64])
        }
    }

    #[test]
    fn pruning_skips_dominated_points_deterministically() {
        // 40 distinct values: the bound-sorted first chunk establishes
        // the global minimum, so every later chunk is dominated
        let space = IntSpace((0..40u64).map(|i| 2000 - i * 3).collect());
        let min_val = *space.0.iter().min().unwrap();
        let run = |workers: usize, prune: bool| {
            let mut calls = 0usize;
            let out = Engine::new(EngineConfig {
                prune,
                ..no_cache_cfg(workers)
            })
            .run(&space, &BoundedEval, |_, _| calls += 1)
            .unwrap();
            assert_eq!(calls, space.len(), "skips must still tick progress");
            out
        };
        let full = run(1, false);
        assert!(full.skipped.is_empty());
        assert_eq!(full.rows.len(), 40);

        let pruned = run(1, true);
        assert_eq!(pruned.rows.len(), PRUNE_CHUNK, "later chunks all skip");
        assert_eq!(pruned.skipped.len(), 40 - PRUNE_CHUNK);
        assert!(pruned.rows.iter().any(|r| r.1 == min_val), "front row survives");
        // the minimized front (here: the single minimum) is identical
        assert_eq!(
            pruned.rows.iter().map(|r| r.1).min(),
            full.rows.iter().map(|r| r.1).min()
        );
        // and the skip set is bit-identical across worker counts
        for workers in [2usize, 8] {
            let p = run(workers, true);
            assert_eq!(p.rows, pruned.rows);
            assert_eq!(p.skipped, pruned.skipped);
        }
    }

    #[test]
    fn objective_ties_are_never_pruned() {
        /// Objective = point % 3: three big tie groups.
        struct ModEval;
        impl Evaluate for ModEval {
            type Point = u64;
            type Row = (usize, u64);
            type Scratch = ();
            fn scratch(&self) {}
            fn evaluate(
                &self,
                index: usize,
                point: &u64,
                _cache: Option<&CostCache>,
                _scratch: &mut (),
            ) -> Vec<(usize, u64)> {
                vec![(index, point % 3)]
            }
            fn lower_bound(
                &self,
                _index: usize,
                point: &u64,
                _scratch: &mut (),
            ) -> Option<Vec<Vec<f64>>> {
                Some(vec![vec![(point % 3) as f64]])
            }
            fn row_objectives(&self, row: &(usize, u64)) -> Option<Vec<f64>> {
                Some(vec![row.1 as f64])
            }
        }
        let space = IntSpace((0..30).collect());
        let out = Engine::new(EngineConfig { prune: true, ..no_cache_cfg(4) })
            .run(&space, &ModEval, |_, _| {})
            .unwrap();
        // every value-0 row ties the incumbent (ties are not dominance),
        // so all 10 survive; the dominated 1s and 2s are skipped
        assert_eq!(out.rows.len(), 10);
        assert!(out.rows.iter().all(|r| r.1 == 0));
        assert_eq!(out.skipped.len(), 20);
    }

    #[test]
    fn pruned_journal_resumes_skips_without_reevaluating() {
        let dir = std::env::temp_dir()
            .join(format!("monet_engine_prune_journal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let space = IntSpace((0..30u64).map(|i| i * 5 + 1).collect());
        let cfg = EngineConfig {
            workers: 2,
            use_cache: false,
            run_dir: Some(dir.clone()),
            ..Default::default()
        };
        let full =
            Engine::new(cfg.clone()).run_journaled(&space, &BoundedEval, |_, _| {}).unwrap();
        assert!(!full.skipped.is_empty(), "pruning must engage");

        /// Refuses to evaluate: the journal must replay rows AND skips.
        struct MustNotRun;
        impl Evaluate for MustNotRun {
            type Point = u64;
            type Row = (usize, u64);
            type Scratch = ();
            fn scratch(&self) {}
            fn evaluate(
                &self,
                _i: usize,
                _p: &u64,
                _c: Option<&CostCache>,
                _s: &mut (),
            ) -> Vec<(usize, u64)> {
                panic!("resume of a complete pruned journal re-evaluated a point")
            }
        }
        let resumed = Engine::new(EngineConfig { resume: true, ..cfg })
            .run_journaled(&space, &MustNotRun, |_, _| {})
            .unwrap();
        assert_eq!(resumed.resumed, space.len(), "skips count as completed");
        assert_eq!(resumed.rows, full.rows);
        assert_eq!(resumed.skipped, full.skipped);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dominance_is_strict_and_nan_safe() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(dominates(&[0.5, 2.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]), "ties do not dominate");
        assert!(!dominates(&[1.0, 4.0], &[2.0, 3.0]), "trade-offs do not dominate");
        assert!(!dominates(&[1.0], &[1.0, 2.0]), "length mismatch");
        assert!(!dominates(&[f64::NAN, 1.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[f64::NAN, 2.0]));
    }

    #[test]
    fn objectives_vector_is_canonically_ordered() {
        let o = Objectives {
            latency_cycles: 2.0,
            energy_pj: 3.0,
            memory_bytes: 5,
            devices: 7,
        };
        assert_eq!(o.to_vec(), vec![2.0, 3.0, 5.0, 7.0]);
    }
}
