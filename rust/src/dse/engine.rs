//! The unified DSE evaluation engine: **one** generic worker-pool harness
//! behind every experiment in the repo.
//!
//! Before this module, the repo carried four hand-rolled copies of the
//! same orchestration — `run_sweep_stats` (single-device accelerator
//! points), `run_cluster_sweep` (homogeneous deployments),
//! `run_hetero_sweep` (stage-placement deployments, cross-noted as a
//! line-for-line mirror of the previous one) and the NSGA-II GA's
//! per-generation batch evaluator — each re-implementing the worker
//! pool, the cost-cache lifecycle and the determinism guarantees by
//! hand. They are now all instances of this API (see
//! [`super::sweep::SweepEval`], [`super::sweep::ClusterEval`],
//! [`super::sweep::HeteroEval`] and [`map_parallel`] in
//! `ga::nsga2::evaluate_batch`), so the next search dimension lands as
//! one [`DesignSpace`] + [`Evaluate`] pair instead of a fifth fork.
//!
//! ## The three pieces
//!
//! * [`DesignSpace`] — a finite, **deterministically ordered** set of
//!   points with **stable ids**: enumerating the same space twice yields
//!   the same points in the same order, and `point_id(i)` is unique
//!   within the space and stable across runs/builds (it names rows in
//!   CSVs, caches and golden tests).
//! * [`Evaluate`] — how one point becomes result rows. One instance is
//!   shared by every worker (`&self`), plus a per-worker [`Evaluate::Scratch`]
//!   for memos that must not be contended across threads.
//! * [`Engine`] — the harness. [`Engine::run`] owns the worker pool
//!   (work-stealing index over scoped threads), the per-worker scratch,
//!   the shared [`CostCache`] **lifecycle** (`use_cache` /
//!   `cache_dir` / `cache_cap` — open, warm-load, bound, persist; the
//!   `--no-cache` escape hatch wins over persistence and skips both load
//!   and save), the progress callback, the cache counters, and the
//!   deterministic result ordering.
//!
//! ## The evaluation contract (what an [`Evaluate`] impl may NOT read)
//!
//! Mirroring the `eval` cost-cache soundness contract
//! (`rust/src/eval/mod.rs`), `Evaluate::evaluate` must be a **pure
//! function** of `(index, point, &self)`. It may not read:
//!
//! * worker identity, thread ids, or how points were distributed over
//!   the pool;
//! * wall-clock time, environment variables, or any global mutable
//!   state;
//! * results of *other* points (each point must evaluate as if alone);
//! * the scratch, except as a **memo of pure functions** of the inputs —
//!   a hit must return bit-identical values to a recompute (the
//!   per-worker training-graph and stage-cuts memos obey this);
//! * the cost cache, except through the passed handle — and only for
//!   values that are themselves pure (the `eval` contract).
//!
//! Anything else breaks the engine's core guarantee, pinned by
//! `tests/dse_engine.rs`: **rows are bit-identical across any worker
//! count and any cache setting** (off / cold / warm-persisted /
//! capacity-bounded).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use super::space::{ClusterPoint, DesignPoint};
use crate::eval::{persist, CacheStats, CostCache};
use crate::parallelism::{HeteroCluster, HeteroPoint};

/// A finite, deterministically ordered set of evaluable design points
/// with stable per-point ids. See the module docs for the contract.
pub trait DesignSpace {
    type Point: Sync;

    /// The points, in the space's canonical (deterministic) order.
    fn points(&self) -> &[Self::Point];

    /// Stable, unique-within-the-space id of the `index`-th point — the
    /// same string the family's [`Evaluate`] impl emits as the row label
    /// (golden tests and CSVs key on it). Uniqueness is enforced in
    /// debug builds by [`Engine::run`], which is what keeps a space's
    /// ids and its evaluator's labels from drifting apart silently.
    fn point_id(&self, index: usize) -> String;

    fn len(&self) -> usize {
        self.points().len()
    }

    fn is_empty(&self) -> bool {
        self.points().is_empty()
    }
}

/// The single-device accelerator space: a slice of [`DesignPoint`]s in
/// enumeration order, identified by their sweep labels.
impl DesignSpace for [DesignPoint] {
    type Point = DesignPoint;

    fn points(&self) -> &[DesignPoint] {
        self
    }

    fn point_id(&self, index: usize) -> String {
        self[index].label()
    }
}

/// The homogeneous deployment space: a slice of [`ClusterPoint`]s in
/// enumeration order, identified by their row labels.
impl DesignSpace for [ClusterPoint] {
    type Point = ClusterPoint;

    fn points(&self) -> &[ClusterPoint] {
        self
    }

    fn point_id(&self, index: usize) -> String {
        self[index].label()
    }
}

/// The heterogeneous stage-placement space: enumerated [`HeteroPoint`]s
/// plus the device pool they are placed on (a point's label needs the
/// pool's class names, so a bare slice cannot implement [`DesignSpace`]).
pub struct HeteroSpace<'a> {
    pub points: &'a [HeteroPoint],
    pub cluster: &'a HeteroCluster,
}

impl DesignSpace for HeteroSpace<'_> {
    type Point = HeteroPoint;

    fn points(&self) -> &[HeteroPoint] {
        self.points
    }

    fn point_id(&self, index: usize) -> String {
        self.points[index].label(self.cluster)
    }
}

/// The minimized objective set every MONET experiment reports — the
/// typed replacement for the ad-hoc `Vec<f64>` rows the sweeps used to
/// hand to the NSGA-II ranking. Single-device rows report `devices = 1`;
/// cluster rows report per-device memory and the cluster size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    pub latency_cycles: f64,
    pub energy_pj: f64,
    pub memory_bytes: u64,
    pub devices: usize,
}

impl Objectives {
    /// The flat minimized vector `ga::nsga2::pareto_rank0` consumes, in
    /// the canonical order (latency, energy, memory, devices).
    pub fn to_vec(self) -> Vec<f64> {
        vec![
            self.latency_cycles,
            self.energy_pj,
            self.memory_bytes as f64,
            self.devices as f64,
        ]
    }
}

/// How one design point becomes result rows. One instance serves the
/// whole pool (`&self` from every worker); per-worker mutable state
/// lives in [`Evaluate::Scratch`]. See the module docs for what an
/// implementation may NOT read.
pub trait Evaluate: Sync {
    type Point: Sync;
    /// One result row; a point may emit several (e.g. one per mode).
    type Row: Send;
    /// Per-worker scratch: memos of pure functions only (training-graph
    /// memo, stage-cuts memo). Created once per worker, never shared.
    type Scratch;

    /// Fresh scratch for one worker.
    fn scratch(&self) -> Self::Scratch;

    /// Evaluate the `index`-th point into rows. `cache` is the
    /// engine-owned shared cost cache (`None` under `--no-cache`).
    fn evaluate(
        &self,
        index: usize,
        point: &Self::Point,
        cache: Option<&CostCache>,
        scratch: &mut Self::Scratch,
    ) -> Vec<Self::Row>;
}

/// The engine's orchestration knobs: worker count plus the shared
/// cost-cache lifecycle (the CLI's `--no-cache` / `--cache-dir` /
/// `--cache-cap` triple — one definition, so the semantics cannot drift
/// across commands).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (1 = serial). Results are bit-identical for every
    /// value — parallelism only changes wall-clock.
    pub workers: usize,
    /// Share one [`CostCache`] across the pool. `false` (the
    /// `--no-cache` escape hatch) recomputes every group cost and
    /// **wins over `cache_dir`**: nothing is loaded or saved.
    pub use_cache: bool,
    /// Persist the cost cache across process runs (`--cache-dir`):
    /// warm-load the snapshot before the run, write it back after.
    /// Stale/incompatible snapshots are rejected wholesale
    /// (see [`crate::eval::persist`]).
    pub cache_dir: Option<PathBuf>,
    /// Bound the cache to ~this many entries with the sharded CLOCK
    /// policy (`--cache-cap`); 0 = unbounded.
    pub cache_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            use_cache: true,
            cache_dir: None,
            cache_cap: 0,
        }
    }
}

/// The generic sweep/search harness. See the module docs.
pub struct Engine {
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        Engine { cfg }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Evaluate every point of `space` over the worker pool and return
    /// the rows plus the shared cache's counters.
    ///
    /// Guarantees (pinned by `tests/dse_engine.rs`):
    ///
    /// * **ordering** — rows come back sorted by point index; a point's
    ///   own rows keep their emission order;
    /// * **determinism** — bit-identical rows for any `workers` value
    ///   and any cache setting (off / cold / warm / bounded);
    /// * **lifecycle** — with `use_cache`, the cache is opened (warm-
    ///   loading a `cache_dir` snapshot when present, bounded by
    ///   `cache_cap`) before evaluation and persisted back after; with
    ///   `use_cache` off nothing is loaded, counted or saved;
    /// * **progress** — `progress(done, total)` fires once per completed
    ///   point, in completion order.
    pub fn run<S, E>(
        &self,
        space: &S,
        eval: &E,
        mut progress: impl FnMut(usize, usize),
    ) -> (Vec<E::Row>, CacheStats)
    where
        S: DesignSpace + ?Sized,
        E: Evaluate<Point = S::Point>,
    {
        let points = space.points();
        let n = points.len();
        #[cfg(debug_assertions)]
        {
            // the DesignSpace id contract: unique within the space
            let mut seen = std::collections::HashSet::with_capacity(n);
            for i in 0..n {
                let id = space.point_id(i);
                assert!(seen.insert(id.clone()), "DesignSpace ids must be unique: {id:?}");
            }
        }
        let cache = if self.cfg.use_cache {
            Some(persist::open_cost_cache(self.cfg.cache_dir.as_deref(), self.cfg.cache_cap))
        } else {
            None
        };
        let cache_ref = cache.as_ref();

        let mut keyed: Vec<(usize, Vec<E::Row>)> = Vec::with_capacity(n);
        let mut done = 0usize;
        run_pool(
            self.cfg.workers,
            n,
            &|| eval.scratch(),
            &|i, scratch: &mut E::Scratch| eval.evaluate(i, &points[i], cache_ref, scratch),
            |i, rows| {
                keyed.push((i, rows));
                done += 1;
                progress(done, n);
            },
        );
        keyed.sort_by_key(|&(i, _)| i);

        let stats = cache.as_ref().map(|c| c.stats()).unwrap_or_default();
        if let Some(c) = &cache {
            persist::persist_cost_cache(c, self.cfg.cache_dir.as_deref());
        }
        (keyed.into_iter().flat_map(|(_, rows)| rows).collect(), stats)
    }
}

/// Deterministic parallel map over a slice: `out[i] == f(&items[i])`
/// for every `i`, regardless of `workers`. This is the engine's pool
/// exposed for callers that own their own caching (the NSGA-II GA's
/// per-generation genome batches); `f` must be pure.
pub fn map_parallel<T, R>(
    workers: usize,
    items: &[T],
    f: impl Fn(&T) -> R + Sync,
) -> Vec<R>
where
    T: Sync,
    R: Send,
{
    let n = items.len();
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    run_pool(
        workers,
        n,
        &|| (),
        &|i, _scratch: &mut ()| f(&items[i]),
        |i, r| out[i] = Some(r),
    );
    out.into_iter().map(|r| r.expect("pool delivered every index")).collect()
}

/// The one worker-pool core every harness shares: a work-stealing index
/// over scoped threads, one `scratch()` per worker, results streamed
/// back to the caller's thread as `(index, result)` via `sink` (in
/// completion order — callers needing index order sort or slot by `i`).
/// Serial (no threads spawned) when one worker suffices.
fn run_pool<R, Sc>(
    workers: usize,
    n: usize,
    scratch: &(impl Fn() -> Sc + Sync),
    task: &(impl Fn(usize, &mut Sc) -> R + Sync),
    mut sink: impl FnMut(usize, R),
) where
    R: Send,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        let mut sc = scratch();
        for i in 0..n {
            sink(i, task(i, &mut sc));
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            scope.spawn(move || {
                let mut sc = scratch();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    if tx.send((i, task(i, &mut sc))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        while let Ok((i, r)) = rx.recv() {
            sink(i, r);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic space: points are integers, ids are their decimal
    /// strings.
    struct IntSpace(Vec<u64>);

    impl DesignSpace for IntSpace {
        type Point = u64;

        fn points(&self) -> &[u64] {
            &self.0
        }

        fn point_id(&self, index: usize) -> String {
            format!("int{}", self.0[index])
        }
    }

    /// Squares each point; the scratch counts this worker's evaluations
    /// (a memo-shaped use: it never alters results).
    struct SquareEval;

    impl Evaluate for SquareEval {
        type Point = u64;
        type Row = (usize, u64);
        type Scratch = usize;

        fn scratch(&self) -> usize {
            0
        }

        fn evaluate(
            &self,
            index: usize,
            point: &u64,
            _cache: Option<&CostCache>,
            scratch: &mut usize,
        ) -> Vec<(usize, u64)> {
            *scratch += 1;
            vec![(index, point * point)]
        }
    }

    fn no_cache_cfg(workers: usize) -> EngineConfig {
        EngineConfig { workers, use_cache: false, ..Default::default() }
    }

    #[test]
    fn rows_are_index_ordered_and_identical_across_worker_counts() {
        let space = IntSpace((0..97).map(|i| i * 3 + 1).collect());
        let run = |workers: usize| {
            let mut calls = 0usize;
            let (rows, stats) =
                Engine::new(no_cache_cfg(workers)).run(&space, &SquareEval, |_, _| calls += 1);
            assert_eq!(calls, space.len());
            assert_eq!(stats, CacheStats::default());
            rows
        };
        let serial = run(1);
        assert_eq!(serial.len(), 97);
        for (i, &(idx, sq)) in serial.iter().enumerate() {
            assert_eq!(idx, i);
            assert_eq!(sq, space.0[i] * space.0[i]);
        }
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(8));
        assert_eq!(serial, run(64), "more workers than points must still work");
    }

    #[test]
    fn multi_row_points_keep_emission_order() {
        struct PairEval;
        impl Evaluate for PairEval {
            type Point = u64;
            type Row = (usize, &'static str);
            type Scratch = ();
            fn scratch(&self) {}
            fn evaluate(
                &self,
                index: usize,
                _point: &u64,
                _cache: Option<&CostCache>,
                _scratch: &mut (),
            ) -> Vec<(usize, &'static str)> {
                vec![(index, "first"), (index, "second")]
            }
        }
        let space = IntSpace((0..13).collect());
        let (rows, _) = Engine::new(no_cache_cfg(4)).run(&space, &PairEval, |_, _| {});
        assert_eq!(rows.len(), 26);
        for (i, pair) in rows.chunks(2).enumerate() {
            assert_eq!(pair[0], (i, "first"));
            assert_eq!(pair[1], (i, "second"));
        }
    }

    #[test]
    fn empty_space_yields_no_rows_and_no_progress() {
        let space = IntSpace(vec![]);
        let mut calls = 0usize;
        let (rows, stats) =
            Engine::new(no_cache_cfg(4)).run(&space, &SquareEval, |_, _| calls += 1);
        assert!(rows.is_empty());
        assert_eq!(calls, 0);
        assert_eq!(stats, CacheStats::default());
    }

    #[test]
    fn map_parallel_matches_serial_map_for_any_worker_count() {
        let items: Vec<u64> = (0..61).map(|i| i * 7).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for workers in [1usize, 2, 3, 8, 100] {
            assert_eq!(map_parallel(workers, &items, |x| x * x + 1), expect);
        }
        let empty: Vec<u64> = vec![];
        assert!(map_parallel(4, &empty, |x| *x).is_empty());
    }

    #[test]
    fn hetero_space_ids_come_from_the_pool() {
        use crate::parallelism::DeviceClass;
        let hc = HeteroCluster::new(vec![(DeviceClass::edge(), 2)]);
        let points = vec![HeteroPoint {
            dp: 1,
            pp: 2,
            microbatches: 2,
            tp: 1,
            placement: vec![0, 0],
        }];
        let space = HeteroSpace { points: &points, cluster: &hc };
        assert_eq!(space.len(), 1);
        assert_eq!(space.point_id(0), points[0].label(&hc));
    }

    #[test]
    fn objectives_vector_is_canonically_ordered() {
        let o = Objectives {
            latency_cycles: 2.0,
            energy_pj: 3.0,
            memory_bytes: 5,
            devices: 7,
        };
        assert_eq!(o.to_vec(), vec![2.0, 3.0, 5.0, 7.0]);
    }
}
