//! The end-user DSE flow: find the best hardware configurations for a
//! workload, fast. Two stages:
//!
//! 1. **Pre-filter** — the AOT Pallas roofline kernel (L1, executed via
//!    PJRT) scores every design point in large batches; configurations
//!    that cannot be competitive are pruned. Falls back to the bit-exact
//!    native twin when no runtime is available.
//! 2. **Detailed evaluation** — the layer-fused scheduler runs only on the
//!    survivors.
//!
//! This is where the three-layer architecture earns its keep on the hot
//! path: the dense regular half of the work runs as one XLA executable,
//! the irregular scheduling half stays in rust.

use std::time::Instant;

use super::prefilter::{accel_to_cfg, graph_to_layers, select_survivors};
use super::space::DesignPoint;
use super::sweep::{
    evaluate_point_cached, pareto_front, Mode, SweepConfig, SweepPartitions, SweepRow,
};
use crate::eval::{persist, CacheStats};
use crate::runtime::cost_kernel::{cost_eval_native, CostKernel};
use crate::workload::graph::Graph;

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Detailed rows for every survivor (training mode).
    pub rows: Vec<SweepRow>,
    /// Indices into `rows` of the latency-energy Pareto front.
    pub front: Vec<usize>,
    pub n_points: usize,
    pub n_survivors: usize,
    pub prefilter_secs: f64,
    pub detail_secs: f64,
    /// Group-cost cache counters of the detailed stage (zeros with
    /// `cfg.use_cache` off).
    pub cache: CacheStats,
}

/// Search `points` for the best training configurations of (`fwd`,`train`).
/// `keep_frac` is the survivor fraction (the paper-style sweep uses 1.0 =
/// no pruning; 0.1 gives ~10× less detailed-scheduling work).
pub fn search(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    kernel: Option<&CostKernel>,
    keep_frac: f64,
) -> SearchOutcome {
    // stage 1: roofline scores on the training graph
    let t0 = Instant::now();
    let accels: Vec<_> = points.iter().map(|p| p.build()).collect();
    let cfgs: Vec<_> = accels.iter().map(accel_to_cfg).collect();
    let layers = graph_to_layers(train);
    let scores = match kernel {
        Some(k) => k.eval(&cfgs, &layers).expect("cost kernel"),
        None => cost_eval_native(&cfgs, &layers),
    };
    let survivors = select_survivors(&scores, keep_frac, 8);
    let prefilter_secs = t0.elapsed().as_secs_f64();

    // stage 2: detailed layer-fused scheduling on the survivors, sharing
    // one group-cost memo across every survivor evaluation
    let t1 = Instant::now();
    let mut cfg = cfg.clone();
    cfg.modes = vec![Mode::Training];
    let parts = SweepPartitions::prepare(fwd, train, &cfg);
    // same cache lifecycle as `run_sweep_stats`: warm-load a persisted
    // snapshot when `cfg.cache_dir` is set, persist it back afterwards
    // (`--no-cache` wins and skips both)
    let cache = if cfg.use_cache {
        Some(persist::open_cost_cache(cfg.cache_dir.as_deref(), cfg.cache_cap))
    } else {
        None
    };
    let mut rows: Vec<SweepRow> = survivors
        .iter()
        .flat_map(|&i| {
            evaluate_point_cached(i, &points[i], fwd, train, &parts, &cfg, cache.as_ref())
        })
        .collect();
    // total_cmp: a degenerate survivor must not abort the whole search
    rows.sort_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles));
    let detail_secs = t1.elapsed().as_secs_f64();

    let stats = cache.as_ref().map(|c| c.stats()).unwrap_or_default();
    if let Some(c) = &cache {
        persist::persist_cost_cache(c, cfg.cache_dir.as_deref());
    }
    let front = pareto_front(&rows);
    SearchOutcome {
        n_points: points.len(),
        n_survivors: rows.len(),
        rows,
        front,
        prefilter_secs,
        detail_secs,
        cache: stats,
    }
}

/// Pruning-quality metric for the ablation: does the pruned search retain
/// the configurations a full sweep would have put on the Pareto front?
/// Returns the fraction of the full front's labels present in `outcome`.
pub fn front_recall(outcome: &SearchOutcome, full: &SearchOutcome) -> f64 {
    let full_front: std::collections::HashSet<&str> =
        full.front.iter().map(|&i| full.rows[i].label.as_str()).collect();
    if full_front.is_empty() {
        return 1.0;
    }
    let kept = full_front
        .iter()
        .filter(|l| outcome.rows.iter().any(|r| r.label == **l))
        .count();
    kept as f64 / full_front.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::workload::models::resnet18;

    fn setup() -> (Graph, Graph, Vec<DesignPoint>) {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        (fwd, tg.graph, DesignPoint::edge_space(211))
    }

    #[test]
    fn full_search_equals_unpruned_sweep() {
        let (fwd, train, points) = setup();
        let cfg = SweepConfig::default();
        let out = search(&points, &fwd, &train, &cfg, None, 1.0);
        assert_eq!(out.n_survivors, points.len());
        assert!(!out.front.is_empty());
    }

    #[test]
    fn pruned_search_is_cheaper_and_retains_the_front() {
        let (fwd, train, points) = setup();
        let cfg = SweepConfig::default();
        let full = search(&points, &fwd, &train, &cfg, None, 1.0);
        let pruned = search(&points, &fwd, &train, &cfg, None, 0.25);
        assert!(pruned.n_survivors < full.n_survivors);
        // the roofline orders configs well enough that the best-latency
        // config survives 25% pruning
        let best_full = &full.rows[0];
        assert!(
            pruned.rows.iter().any(|r| r.label == best_full.label),
            "best config pruned away"
        );
        let recall = front_recall(&pruned, &full);
        assert!(recall >= 0.5, "front recall {recall} too low");
    }

    #[test]
    fn cache_does_not_change_search_results() {
        let (fwd, train, points) = setup();
        let cached = search(&points, &fwd, &train, &SweepConfig::default(), None, 0.5);
        let plain = search(
            &points,
            &fwd,
            &train,
            &SweepConfig { use_cache: false, ..Default::default() },
            None,
            0.5,
        );
        assert!(cached.cache.hits > 0);
        assert_eq!(plain.cache.hits, 0);
        assert_eq!(cached.front, plain.front);
        for (a, b) in cached.rows.iter().zip(&plain.rows) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        }
    }

    #[test]
    fn rows_sorted_by_latency() {
        let (fwd, train, points) = setup();
        let out = search(&points, &fwd, &train, &SweepConfig::default(), None, 0.5);
        for w in out.rows.windows(2) {
            assert!(w[0].latency_cycles <= w[1].latency_cycles);
        }
    }
}
