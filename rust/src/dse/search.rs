//! The end-user DSE flow: find the best hardware configurations for a
//! workload, fast. Two stages:
//!
//! 1. **Pre-filter** — the AOT Pallas roofline kernel (L1, executed via
//!    PJRT) scores every design point in large batches; configurations
//!    that cannot be competitive are pruned. Falls back to the bit-exact
//!    native twin when no runtime is available.
//! 2. **Detailed evaluation** — the layer-fused scheduler runs only on the
//!    survivors.
//!
//! This is where the three-layer architecture earns its keep on the hot
//! path: the dense regular half of the work runs as one XLA executable,
//! the irregular scheduling half stays in rust.

use std::collections::{HashMap, HashSet};
use std::hash::Hash;
use std::time::Instant;

use super::engine::{Engine, Evaluate, PointFailure};
use super::journal;
use super::prefilter::{accel_to_cfg, graph_to_layers, select_survivors};
use super::space::{ClusterSpace, DesignPoint};
use super::sweep::{
    pareto_front, run_cluster_sweep_outcome, run_hetero_sweep_outcome, ClusterRow,
    ClusterScratch, HeteroEval, Mode, SweepConfig, SweepEval, SweepPartitions, SweepRow,
};
use crate::autodiff::TrainingGraph;
use crate::eval::{CacheStats, CostCache, StructuralHasher};
use crate::ga::nsga2::{nsga2_problem, pareto_rank0, GaConfig, GaStats};
use crate::ga::{DeploymentGenome, DeploymentProblem};
use crate::hardware::accelerator::Accelerator;
use crate::parallelism::{HeteroCluster, LinkTier};
use crate::runtime::cost_kernel::{cost_eval_native, CostKernel};
use crate::workload::graph::Graph;

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Detailed rows for every survivor (training mode).
    pub rows: Vec<SweepRow>,
    /// Indices into `rows` of the latency-energy Pareto front.
    pub front: Vec<usize>,
    pub n_points: usize,
    pub n_survivors: usize,
    pub prefilter_secs: f64,
    pub detail_secs: f64,
    /// Group-cost cache counters of the detailed stage (zeros with
    /// `cfg.use_cache` off).
    pub cache: CacheStats,
    /// Survivors whose detailed evaluation panicked — isolated by the
    /// engine, reported with original point indices, absent from `rows`.
    pub failures: Vec<PointFailure>,
    /// Survivors replayed from a resumed `cfg.run_dir` journal instead of
    /// re-evaluated (0 without `--resume`).
    pub resumed: usize,
}

/// Search `points` for the best training configurations of (`fwd`,`train`).
/// `keep_frac` is the survivor fraction (the paper-style sweep uses 1.0 =
/// no pruning; 0.1 gives ~10× less detailed-scheduling work).
pub fn search(
    points: &[DesignPoint],
    fwd: &Graph,
    train: &Graph,
    cfg: &SweepConfig,
    kernel: Option<&CostKernel>,
    keep_frac: f64,
) -> SearchOutcome {
    // stage 1: roofline scores on the training graph
    let t0 = Instant::now();
    let accels: Vec<_> = points.iter().map(|p| p.build()).collect();
    let cfgs: Vec<_> = accels.iter().map(accel_to_cfg).collect();
    let layers = graph_to_layers(train);
    let scores = match kernel {
        Some(k) => k.eval(&cfgs, &layers).expect("cost kernel"),
        None => cost_eval_native(&cfgs, &layers),
    };
    let survivors = select_survivors(&scores, keep_frac, 8);
    let prefilter_secs = t0.elapsed().as_secs_f64();

    // stage 2: detailed layer-fused scheduling on the survivors through
    // the generic engine harness (same worker pool and cache lifecycle
    // as every sweep family: `--no-cache` wins, `--cache-dir` snapshots
    // warm-load/persist, `--cache-cap` bounds), sharing one group-cost
    // memo across every survivor evaluation
    let t1 = Instant::now();
    let mut cfg = cfg.clone();
    cfg.modes = vec![Mode::Training];
    // the staged search prunes with its own roofline prefilter (stage 1)
    // and reports *every* survivor row (a ranked list, not just a front),
    // so the engine's bound-based front pruning must stay out of stage 2
    cfg.prune = false;
    let parts = SweepPartitions::prepare(fwd, train, &cfg);
    let survivor_points: Vec<DesignPoint> = survivors.iter().map(|&i| points[i]).collect();
    let eval = SweepEval { fwd, train, parts: &parts, cfg: &cfg };
    let mut out = Engine::new(cfg.engine())
        .run_journaled(&survivor_points[..], &eval, |_, _| {})
        .unwrap_or_else(|e| panic!("search failed: {e}"));
    // the engine indexes the survivor slice; report original point indices
    for r in out.rows.iter_mut() {
        r.index = survivors[r.index];
    }
    for f in out.failures.iter_mut() {
        f.index = survivors[f.index];
    }
    // total_cmp: a degenerate survivor must not abort the whole search
    out.rows.sort_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles));
    let detail_secs = t1.elapsed().as_secs_f64();

    let front = pareto_front(&out.rows);
    SearchOutcome {
        n_points: points.len(),
        n_survivors: out.rows.len() + out.failures.len(),
        rows: out.rows,
        front,
        prefilter_secs,
        detail_secs,
        cache: out.cache,
        failures: out.failures,
        resumed: out.resumed,
    }
}

// ---------------------------------------------------------------------------
// Cluster-scale search: the deployment space of §II-C1 / Fig 5
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ClusterSearchOutcome {
    /// One row per enumerated deployment point, in enumeration order.
    pub rows: Vec<ClusterRow>,
    /// Indices into `rows` of the four-objective NSGA-II rank-0 front
    /// (iteration latency, energy, per-device memory, cluster size — all
    /// minimized).
    pub front: Vec<usize>,
    pub n_points: usize,
    pub secs: f64,
    /// Group-cost cache counters of the stage schedules (zeros with
    /// `cfg.use_cache` off).
    pub cache: CacheStats,
    /// Deployment points whose evaluation panicked — isolated by the
    /// engine, absent from `rows`.
    pub failures: Vec<PointFailure>,
    /// Points replayed from a resumed `cfg.run_dir` journal instead of
    /// re-evaluated (0 without `--resume`).
    pub resumed: usize,
    /// Points skipped by bound-based front pruning (`cfg.prune`): their
    /// roofline lower bound was already dominated by evaluated rows, so
    /// they are absent from `rows` — and provably absent from the
    /// rank-0 `front`, which is bit-identical with pruning on or off.
    pub skipped: usize,
}

/// Enumerate and evaluate a [`ClusterSpace`] for one training workload
/// and rank it with the four-objective NSGA-II dominance set. The inner
/// per-device stage schedules share the sweep's group-cost cache (see
/// [`run_cluster_sweep_outcome`]); `cfg.mapping` is the single-device mapping and
/// `builder(batch)` must be pure in the batch size.
pub fn cluster_search(
    space: &ClusterSpace,
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    accel: &Accelerator,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> ClusterSearchOutcome {
    let t0 = Instant::now();
    let points = space.enumerate();
    let out = run_cluster_sweep_outcome(&points, full_batch, builder, accel, cfg, progress)
        .unwrap_or_else(|e| panic!("cluster search failed: {e}"));
    let objectives: Vec<Vec<f64>> = out.rows.iter().map(|r| r.objectives().to_vec()).collect();
    let front = pareto_rank0(&objectives);
    ClusterSearchOutcome {
        n_points: points.len(),
        front,
        rows: out.rows,
        secs: t0.elapsed().as_secs_f64(),
        cache: out.cache,
        failures: out.failures,
        resumed: out.resumed,
        skipped: out.skipped.len(),
    }
}

/// Enumerate and evaluate the **heterogeneous** deployment space of a
/// device pool — factorizations × stage placements × microbatch options
/// (see [`ClusterSpace::enumerate_hetero`]) — and rank it with the same
/// four-objective NSGA-II dominance set as [`cluster_search`]. The inner
/// per-stage schedules ride the shared group-cost cache; `cfg.mapping` is
/// the single-device mapping and `builder(batch)` must be pure in the
/// batch size.
pub fn hetero_search(
    hc: &HeteroCluster,
    microbatches: &[usize],
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> ClusterSearchOutcome {
    let t0 = Instant::now();
    let points = ClusterSpace::enumerate_hetero(hc, microbatches);
    let out = run_hetero_sweep_outcome(&points, hc, full_batch, builder, cfg, progress)
        .unwrap_or_else(|e| panic!("hetero search failed: {e}"));
    let objectives: Vec<Vec<f64>> = out.rows.iter().map(|r| r.objectives().to_vec()).collect();
    let front = pareto_rank0(&objectives);
    ClusterSearchOutcome {
        n_points: points.len(),
        front,
        rows: out.rows,
        secs: t0.elapsed().as_secs_f64(),
        cache: out.cache,
        failures: out.failures,
        resumed: out.resumed,
        skipped: out.skipped.len(),
    }
}

// ---------------------------------------------------------------------------
// GA cluster search: past the exhaustive-enumeration walls
// ---------------------------------------------------------------------------

/// Outcome of [`ga_cluster_search`]: the NSGA-II deployment search over a
/// heterogeneous pool, reported head-to-head against the contiguous-block
/// fallback enumeration it replaces on large pools.
#[derive(Debug, Clone)]
pub struct GaClusterOutcome {
    /// One evaluated row per member of the final rank-0 front — the
    /// four-objective dominance set over everything the search saw
    /// (fallback backbone ∪ GA front) — in deterministic order. By
    /// construction every `fallback_front` row is weakly dominated by
    /// some row here.
    pub rows: Vec<ClusterRow>,
    /// The block-fallback enumeration's own rank-0 front: the baseline
    /// the GA front is compared against.
    pub fallback_front: Vec<ClusterRow>,
    /// GA counters: genomes evaluated vs memo hits, generations
    /// completed, offspring repair rate.
    pub stats: GaStats,
    /// Deployment points the search actually evaluates end to end: the
    /// fallback backbone (minus any bound-pruned points) plus the GA's
    /// fresh genome evaluations.
    pub evaluated: usize,
    /// Backbone points skipped by bound-based front pruning
    /// (`cfg.prune`): dominated before evaluation, so absent from the
    /// ranking — which is bit-identical with pruning on or off.
    pub skipped: usize,
    /// Exact size of the full exhaustive enumeration this search avoids
    /// ([`ClusterSpace::count_hetero`]) — the denominator of the ≤10%
    /// acceptance bar.
    pub enumerated: u64,
    pub secs: f64,
    /// Backbone sweep group-cost cache counters.
    pub cache: CacheStats,
    /// GA-phase group-cost cache counters.
    pub ga_cache: CacheStats,
    /// Backbone points replayed from a resumed `cfg.run_dir` journal.
    pub resumed: usize,
    /// Whether the GA resumed from an intact `ga_journal.bin` checkpoint.
    pub ga_resumed: bool,
    /// Backbone evaluations that panicked — isolated by the engine,
    /// absent from the ranking.
    pub failures: Vec<PointFailure>,
}

/// Run digest of a GA cluster search: pool identity (class names, tiers,
/// energy scales, counts), microbatch menu, batch size, workload tag, and
/// every GA parameter that shapes the stream of generations. `workers`
/// is deliberately excluded — results are bit-identical across worker
/// counts, so a different `--workers` must not invalidate a resume
/// (mirrors `CheckpointProblem::ga_run_digest`).
fn ga_cluster_digest(
    hc: &HeteroCluster,
    microbatches: &[usize],
    full_batch: usize,
    workload: &str,
    ga: &GaConfig<DeploymentGenome>,
) -> u128 {
    let mut h = StructuralHasher::new();
    workload.hash(&mut h);
    full_batch.hash(&mut h);
    microbatches.hash(&mut h);
    hc.counts.hash(&mut h);
    for c in &hc.classes {
        c.name.hash(&mut h);
        c.tier.as_str().hash(&mut h);
        c.energy_scale.to_bits().hash(&mut h);
    }
    ga.population.hash(&mut h);
    ga.generations.hash(&mut h);
    ga.crossover_p.to_bits().hash(&mut h);
    ga.mutation_p.to_bits().hash(&mut h);
    ga.seed.hash(&mut h);
    for g in &ga.seeds {
        (g.dp, g.pp, g.microbatches, g.tp, &g.placement).hash(&mut h);
    }
    h.finish128()
}

/// Search a heterogeneous pool **past the exhaustive-enumeration wall**
/// with the generic NSGA-II core. Two phases:
///
/// 1. **Backbone** — evaluate the contiguous-block fallback enumeration
///    ([`ClusterSpace::enumerate_hetero_fallback`], what `cluster hetero`
///    would enumerate on a pool this size) through the standard journaled
///    engine. Its rank-0 front is the head-to-head baseline *and* the
///    GA's seed population.
/// 2. **GA** — evolve [`DeploymentGenome`]s: full `(dp, pp, m, tp)`
///    factorizations with free per-stage class placements the block
///    fallback never visits. The memo is preloaded with every backbone
///    row, so seeds cost nothing and the final ranking sees the whole
///    baseline.
///
/// The returned front is the rank-0 set over everything the search saw
/// (backbone ∪ GA front), so it weakly dominates every fallback front
/// row by construction while visiting a small fraction of
/// [`ClusterSpace::count_hetero`].
///
/// Determinism: rows are bit-identical for any worker count, with or
/// without the shared cost cache, and across `--resume` at any
/// generation boundary (the backbone replays from `run_journal.bin`, the
/// GA from `ga_journal.bin`; both live in `cfg.run_dir`, and an
/// unopenable GA journal degrades to an unjournaled search with a
/// warning). `workload` tags the GA journal's run digest; `builder` must
/// be pure in the batch size.
#[allow(clippy::too_many_arguments)]
pub fn ga_cluster_search(
    hc: &HeteroCluster,
    microbatches: &[usize],
    full_batch: usize,
    builder: &(dyn Fn(usize) -> TrainingGraph + Sync),
    workload: &str,
    ga: &GaConfig<DeploymentGenome>,
    cfg: &SweepConfig,
    progress: impl FnMut(usize, usize),
) -> GaClusterOutcome {
    let t0 = Instant::now();

    // phase 1: the block-fallback backbone, through the journaled engine
    // (worker pool, cache lifecycle, crash-safety all standard)
    let points = ClusterSpace::enumerate_hetero_fallback(hc, microbatches);
    let out = run_hetero_sweep_outcome(&points, hc, full_batch, builder, cfg, progress)
        .unwrap_or_else(|e| panic!("ga-cluster backbone failed: {e}"));
    let fb_objs: Vec<Vec<f64>> = out.rows.iter().map(|r| r.objectives().to_vec()).collect();
    let fb_front_idx = pareto_rank0(&fb_objs);
    let fallback_front: Vec<ClusterRow> =
        fb_front_idx.iter().map(|&i| out.rows[i].clone()).collect();

    let mut memo: HashMap<DeploymentGenome, Vec<f64>> = HashMap::new();
    for (r, o) in out.rows.iter().zip(&fb_objs) {
        memo.insert(ClusterSpace::hetero_to_genome(&points[r.index]), o.clone());
    }

    let mut ga = ga.clone();
    if ga.seeds.is_empty() {
        ga.seeds = fb_front_idx
            .iter()
            .map(|&i| ClusterSpace::hetero_to_genome(&points[out.rows[i].index]))
            .collect();
    }

    // phase 2: the GA, on the caller's resident cache when one is shared
    // (`monet serve`), else its own fresh cost cache (the engine owns the
    // backbone's for its lifecycle) — cached and uncached evaluations are
    // bit-identical, so the cache's temperature is a cost, never a skew
    let ga_cache: Option<std::sync::Arc<CostCache>> = if cfg.use_cache {
        Some(match &cfg.shared_cache {
            Some(shared) => shared.0.clone(),
            None => std::sync::Arc::new(if cfg.cache_cap > 0 {
                CostCache::with_capacity(cfg.cache_cap)
            } else {
                CostCache::new()
            }),
        })
    } else {
        None
    };
    let heval = HeteroEval { hc, full_batch, builder, mapping: cfg.mapping };
    // Incremental GA evaluation (ROADMAP item 5): genome mutations touch
    // one factorization knob or one stage placement, so most of a
    // mutant's stage schedules are already in a sibling's scratch memos
    // (training graphs, latency-balanced cuts, per-stage StageEval rows —
    // see `parallelism::StageCutsMemo`). Recycling scratches through a
    // pool instead of building a fresh one per genome turns each
    // re-evaluation into "re-cost only the changed stages". Memos are
    // pure-function caches, so a warm scratch is bit-identical to a cold
    // one — pinned per generation by `tests/front_equivalence.rs`.
    let scratch_pool: std::sync::Mutex<Vec<ClusterScratch>> = std::sync::Mutex::new(Vec::new());
    let eval = |g: &DeploymentGenome| {
        let p = ClusterSpace::genome_to_hetero(g);
        let mut scratch =
            scratch_pool.lock().ok().and_then(|mut v| v.pop()).unwrap_or_default();
        let objs =
            heval.evaluate(0, &p, ga_cache.as_deref(), &mut scratch)[0].objectives().to_vec();
        if let Ok(mut v) = scratch_pool.lock() {
            v.push(scratch);
        }
        objs
    };
    let problem = DeploymentProblem { hc, microbatches: microbatches.to_vec() };
    let (ga_front, stats, ga_resumed) = match &cfg.run_dir {
        Some(dir) => {
            let digest = ga_cluster_digest(hc, microbatches, full_batch, workload, &ga);
            let path = dir.join(journal::GA_JOURNAL_FILE);
            match journal::open_journal(&path, journal::GA_JOURNAL_MAGIC, digest, cfg.resume) {
                Ok((payloads, mut file)) => {
                    let resume_cp = payloads
                        .iter()
                        .rev()
                        .find_map(|p| journal::decode_ga_checkpoint::<DeploymentGenome>(p));
                    let ga_resumed = resume_cp.is_some();
                    let mut dead = false;
                    let (front, stats) =
                        nsga2_problem(&problem, &ga, eval, &mut memo, resume_cp, |cp| {
                            if dead {
                                return;
                            }
                            if let Err(e) =
                                file.append_record(&journal::encode_ga_checkpoint(cp))
                            {
                                dead = true;
                                eprintln!(
                                    "warning: GA journal write to {} failed ({e}); \
                                     continuing without further checkpoints",
                                    path.display()
                                );
                            }
                        });
                    (front, stats, ga_resumed)
                }
                Err(e) => {
                    eprintln!(
                        "warning: GA journal {} unavailable ({e}); running without crash-safety",
                        path.display()
                    );
                    let (front, stats) = nsga2_problem(&problem, &ga, eval, &mut memo, None, |_| {});
                    (front, stats, false)
                }
            }
        }
        None => {
            let (front, stats) = nsga2_problem(&problem, &ga, eval, &mut memo, None, |_| {});
            (front, stats, false)
        }
    };

    // final front: rank-0 over the union of the backbone and the GA's
    // front. The union contains every backbone row, so each fallback
    // front row is weakly dominated by some member (itself, whenever the
    // GA found nothing strictly better there).
    let backbone_genomes: HashSet<DeploymentGenome> =
        out.rows.iter().map(|r| ClusterSpace::hetero_to_genome(&points[r.index])).collect();
    let extra: Vec<(DeploymentGenome, Vec<f64>)> = ga_front
        .iter()
        .filter(|ind| !backbone_genomes.contains(&ind.genome))
        .map(|ind| (ind.genome.clone(), ind.objectives.clone()))
        .collect();
    let mut union_objs = fb_objs;
    union_objs.extend(extra.iter().map(|(_, o)| o.clone()));
    let front_idx = pareto_rank0(&union_objs);
    // re-derive full rows with a warm scratch from the GA pool: the front
    // genomes were all costed during the run, so this is pure memo replay
    let mut scratch =
        scratch_pool.lock().ok().and_then(|mut v| v.pop()).unwrap_or_default();
    let mut rows = Vec::with_capacity(front_idx.len());
    for &i in &front_idx {
        if i < out.rows.len() {
            rows.push(out.rows[i].clone());
        } else {
            // a GA discovery outside the backbone: derive its full row by
            // re-evaluating the pure model (bit-identical to the GA's own
            // evaluation); its index continues past the backbone's
            let off = i - out.rows.len();
            let p = ClusterSpace::genome_to_hetero(&extra[off].0);
            rows.push(
                heval
                    .evaluate(points.len() + off, &p, ga_cache.as_deref(), &mut scratch)
                    .remove(0),
            );
        }
    }

    GaClusterOutcome {
        rows,
        fallback_front,
        stats,
        evaluated: points.len() - out.skipped.len() + stats.evaluated,
        skipped: out.skipped.len(),
        enumerated: ClusterSpace::count_hetero(hc, microbatches),
        secs: t0.elapsed().as_secs_f64(),
        cache: out.cache,
        ga_cache: ga_cache.as_deref().map(|c| c.stats()).unwrap_or_default(),
        resumed: out.resumed,
        ga_resumed,
        failures: out.failures,
    }
}

/// Is this row a uniform placement hosted entirely on the named class?
/// (Homogeneous rows have an empty placement and are never uniform-`c`.)
pub fn placed_only_on(row: &ClusterRow, class: &str) -> bool {
    !row.placement.is_empty() && row.placement.split('|').all(|c| c == class)
}

/// Does this row's placement span more than one device class?
pub fn mixed_placement(row: &ClusterRow) -> bool {
    let mut it = row.placement.split('|');
    let first = it.next();
    !row.placement.is_empty() && it.any(|c| Some(c) != first)
}

/// The heterogeneity acceptance witness: the index (into `outcome.rows`)
/// of a **mixed-placement front row** that beats the best uniform-
/// `lat_class` row on latency *and* the best uniform-`en_class` row on
/// energy. For an edge+datacenter pool this is the paper's §II-C1 claim
/// made executable: splitting the pipeline so the memory-heavy stages run
/// on datacenter-class devices outruns every all-edge deployment while
/// out-frugaling every all-datacenter one. Returns `None` when no front
/// row does both.
pub fn mixed_domination_witness(
    outcome: &ClusterSearchOutcome,
    lat_class: &str,
    en_class: &str,
) -> Option<usize> {
    let rows = &outcome.rows;
    let best_lat = rows
        .iter()
        .filter(|r| placed_only_on(r, lat_class))
        .map(|r| r.latency_cycles)
        .fold(f64::INFINITY, f64::min);
    let best_en = rows
        .iter()
        .filter(|r| placed_only_on(r, en_class))
        .map(|r| r.energy_pj)
        .fold(f64::INFINITY, f64::min);
    outcome.front.iter().copied().find(|&i| {
        let r = &rows[i];
        mixed_placement(r) && r.latency_cycles < best_lat && r.energy_pj < best_en
    })
}

/// Distinct `(dp, pp, tp)` factorizations among the front rows, sorted.
/// The acceptance bar for a non-degenerate cluster front is ≥3 of these.
pub fn front_factorizations(outcome: &ClusterSearchOutcome) -> Vec<(usize, usize, usize)> {
    let mut v: Vec<(usize, usize, usize)> = outcome
        .front
        .iter()
        .map(|&i| outcome.rows[i].factorization())
        .collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Latency-optimal factorization of one (tier, device-count) slice — the
/// quantity whose edge↔datacenter flip the Fig 5 front visualizes.
pub fn best_latency_factorization(
    rows: &[ClusterRow],
    tier: LinkTier,
    devices: usize,
) -> Option<(usize, usize, usize)> {
    rows.iter()
        .filter(|r| r.tier == tier && r.devices == devices)
        .min_by(|a, b| a.latency_cycles.total_cmp(&b.latency_cycles))
        .map(|r| r.factorization())
}

/// Pruning-quality metric for the ablation: does the pruned search retain
/// the configurations a full sweep would have put on the Pareto front?
/// Returns the fraction of the full front's labels present in `outcome`.
pub fn front_recall(outcome: &SearchOutcome, full: &SearchOutcome) -> f64 {
    let full_front: std::collections::HashSet<&str> =
        full.front.iter().map(|&i| full.rows[i].label.as_str()).collect();
    if full_front.is_empty() {
        return 1.0;
    }
    let kept = full_front
        .iter()
        .filter(|l| outcome.rows.iter().any(|r| r.label == **l))
        .count();
    kept as f64 / full_front.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::{build_training_graph, TrainOptions};
    use crate::workload::models::resnet18;

    fn setup() -> (Graph, Graph, Vec<DesignPoint>) {
        let fwd = resnet18(1, 32, 10);
        let tg = build_training_graph(&fwd, TrainOptions::default());
        (fwd, tg.graph, DesignPoint::edge_space(211))
    }

    #[test]
    fn full_search_equals_unpruned_sweep() {
        let (fwd, train, points) = setup();
        let cfg = SweepConfig::default();
        let out = search(&points, &fwd, &train, &cfg, None, 1.0);
        assert_eq!(out.n_survivors, points.len());
        assert!(!out.front.is_empty());
    }

    #[test]
    fn pruned_search_is_cheaper_and_retains_the_front() {
        let (fwd, train, points) = setup();
        let cfg = SweepConfig::default();
        let full = search(&points, &fwd, &train, &cfg, None, 1.0);
        let pruned = search(&points, &fwd, &train, &cfg, None, 0.25);
        assert!(pruned.n_survivors < full.n_survivors);
        // the roofline orders configs well enough that the best-latency
        // config survives 25% pruning
        let best_full = &full.rows[0];
        assert!(
            pruned.rows.iter().any(|r| r.label == best_full.label),
            "best config pruned away"
        );
        let recall = front_recall(&pruned, &full);
        assert!(recall >= 0.5, "front recall {recall} too low");
    }

    #[test]
    fn cache_does_not_change_search_results() {
        let (fwd, train, points) = setup();
        let cached = search(&points, &fwd, &train, &SweepConfig::default(), None, 0.5);
        let plain = search(
            &points,
            &fwd,
            &train,
            &SweepConfig { use_cache: false, ..Default::default() },
            None,
            0.5,
        );
        assert!(cached.cache.hits > 0);
        assert_eq!(plain.cache.hits, 0);
        assert_eq!(cached.front, plain.front);
        for (a, b) in cached.rows.iter().zip(&plain.rows) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        }
    }

    #[test]
    fn rows_sorted_by_latency() {
        let (fwd, train, points) = setup();
        let out = search(&points, &fwd, &train, &SweepConfig::default(), None, 0.5);
        for w in out.rows.windows(2) {
            assert!(w[0].latency_cycles <= w[1].latency_cycles);
        }
    }

    /// Shared by the two acceptance tests below (the evaluation is the
    /// expensive part; the assertions are not).
    fn gpt2_cluster_outcome() -> &'static ClusterSearchOutcome {
        use crate::hardware::presets::EdgeTpuParams;
        use crate::mapping::MappingConfig;

        static OUT: std::sync::OnceLock<ClusterSearchOutcome> = std::sync::OnceLock::new();
        OUT.get_or_init(|| {
            let space = ClusterSpace {
                device_counts: vec![4, 8],
                tiers: vec![LinkTier::Edge, LinkTier::Datacenter],
                microbatches: vec![2, 4],
            };
            let accel = EdgeTpuParams::baseline().build();
            let cfg = SweepConfig {
                mapping: MappingConfig::edge_tpu_default(),
                ..Default::default()
            };
            // the canonical fig5 workload — the acceptance tests must pin
            // exactly what the CLI/figure produce
            cluster_search(
                &space,
                4,
                &crate::figures::cluster_gpt2_builder,
                &accel,
                &cfg,
                |_, _| {},
            )
        })
    }

    #[test]
    fn gpt2_cluster_front_is_non_degenerate_on_4plus_devices() {
        let out = gpt2_cluster_outcome();
        assert_eq!(out.n_points, out.rows.len());
        assert!(!out.front.is_empty());
        assert!(out.cache.hits > 0, "stage schedules repeated across tiers must share costs");
        // every enumerated point sits on ≥4 devices, so the front bar
        // applies to the whole outcome: at least three distinct DP/PP/TP
        // factorizations must survive the four-objective ranking
        assert!(out.rows.iter().all(|r| r.devices >= 4));
        let facts = front_factorizations(out);
        assert!(
            facts.len() >= 3,
            "degenerate cluster front — only {} factorization(s): {facts:?}",
            facts.len()
        );
    }

    #[test]
    fn gpt2_mixed_cluster_front_dominates_the_uniform_extremes() {
        use crate::mapping::MappingConfig;
        use crate::parallelism::{DeviceClass, HeteroCluster};

        // the edge-to-datacenter acceptance bar: on an edge:2+datacenter:2
        // pool training tiny GPT-2, the 4-objective front must contain a
        // mixed-placement point that is faster than every all-edge
        // deployment (datacenter-class stages soak up the latency) and
        // cheaper than every all-datacenter deployment (edge-class stages
        // dodge the V²·f energy scale)
        let hc = HeteroCluster::new(vec![
            (DeviceClass::edge(), 2),
            (DeviceClass::datacenter(), 2),
        ]);
        let cfg = SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            ..Default::default()
        };
        let out = hetero_search(
            &hc,
            &[2, 4],
            4,
            &crate::figures::cluster_gpt2_builder,
            &cfg,
            |_, _| {},
        );
        assert_eq!(out.n_points, out.rows.len());
        assert!(!out.front.is_empty());
        assert!(out.cache.hits > 0, "placements repeating stage shapes must share costs");
        // both uniform extremes actually exist in the enumerated space
        assert!(out.rows.iter().any(|r| placed_only_on(r, "edge")));
        assert!(out.rows.iter().any(|r| placed_only_on(r, "datacenter")));
        assert!(out.rows.iter().any(|r| mixed_placement(r)));
        let w = mixed_domination_witness(&out, "edge", "datacenter");
        assert!(
            w.is_some(),
            "no mixed-placement front point dominates the best all-edge latency \
             and the best all-datacenter energy"
        );
        let witness = &out.rows[w.unwrap()];
        assert!(
            witness.placement.contains("datacenter") && witness.placement.contains("edge"),
            "witness must span both classes: {}",
            witness.placement
        );
    }

    /// The `ga-cluster` acceptance workload: a pool two orders of
    /// magnitude past `MAX_EXHAUSTIVE_PLACEMENT` wants a tiny per-device
    /// model so the backbone sweep stays fast.
    fn tiny_mlp_builder(batch: usize) -> crate::autodiff::TrainingGraph {
        build_training_graph(
            &crate::workload::models::mlp(batch.max(1), 8, 16, 2, 4),
            TrainOptions::default(),
        )
    }

    fn big_pool() -> crate::parallelism::HeteroCluster {
        use crate::parallelism::{DeviceClass, HeteroCluster};
        HeteroCluster::new(vec![
            (DeviceClass::edge(), 128),
            (DeviceClass::server(), 64),
            (DeviceClass::datacenter(), 64),
        ])
    }

    fn run_ga_cluster(
        workers: usize,
        run_dir: Option<std::path::PathBuf>,
        resume: bool,
    ) -> super::GaClusterOutcome {
        use crate::ga::nsga2::GaConfig;
        use crate::mapping::MappingConfig;

        let hc = big_pool();
        let ga = GaConfig {
            population: 16,
            generations: 6,
            workers,
            seed: 9,
            ..Default::default()
        };
        let cfg = SweepConfig {
            mapping: MappingConfig::edge_tpu_default(),
            workers,
            run_dir,
            resume,
            ..Default::default()
        };
        super::ga_cluster_search(&hc, &[2], 4, &tiny_mlp_builder, "tiny-mlp", &ga, &cfg, |_, _| {})
    }

    fn assert_rows_equal(a: &[ClusterRow], b: &[ClusterRow]) {
        assert_eq!(a.len(), b.len(), "front sizes differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.index, y.index);
            assert_eq!(x.label, y.label);
            assert_eq!(x.placement, y.placement);
            assert_eq!(
                (x.dp, x.pp, x.microbatches, x.tp, x.devices),
                (y.dp, y.pp, y.microbatches, y.tp, y.devices)
            );
            assert_eq!(x.latency_cycles.to_bits(), y.latency_cycles.to_bits());
            assert_eq!(x.energy_pj.to_bits(), y.energy_pj.to_bits());
            assert_eq!(x.per_device_mem_bytes, y.per_device_mem_bytes);
            assert_eq!(x.comm_bytes.to_bits(), y.comm_bytes.to_bits());
        }
    }

    /// The ISSUE 7 acceptance bar: on a 256-device edge+server+datacenter
    /// pool the GA search (a) weakly dominates every row of the
    /// block-fallback enumeration front, (b) visits ≤ 10% as many points
    /// as the full exhaustive enumeration it replaces, and (c) is
    /// bit-identical across 1/2/8 workers.
    #[test]
    fn ga_cluster_beats_the_block_fallback_on_a_256_device_pool() {
        assert_eq!(big_pool().total_devices(), 256);
        let base = run_ga_cluster(1, None, false);
        assert!(!base.rows.is_empty() && !base.fallback_front.is_empty());
        assert!(base.failures.is_empty(), "backbone evaluations panicked: {:?}", base.failures);
        // (a) every fallback front row is weakly dominated by some member
        // of the GA front
        for fb in &base.fallback_front {
            let fo = fb.objectives().to_vec();
            assert!(
                base.rows.iter().any(|r| r
                    .objectives()
                    .to_vec()
                    .iter()
                    .zip(&fo)
                    .all(|(a, b)| a <= b)),
                "fallback front row {} escapes the GA front",
                fb.label
            );
        }
        // (b) the whole search — backbone plus fresh GA evaluations —
        // visits ≤ 10% of what exhaustive enumeration would
        assert!(
            base.evaluated as u64 * 10 <= base.enumerated,
            "{} points visited vs {} enumerable — over the 10% bar",
            base.evaluated,
            base.enumerated
        );
        // the stats satellite reports real work: fresh evaluations, memo
        // hits (anchors and seeds are preloaded from the backbone), all
        // generations, and offspring accounting that adds up
        assert_eq!(base.stats.generations, 6);
        assert!(base.stats.evaluated > 0, "GA never left the backbone");
        assert!(base.stats.memo_hits > 0, "preloaded seeds must hit the memo");
        assert_eq!(base.stats.produced, 16 * 7, "population × (generations + 1)");
        assert!(base.stats.repair_rate() <= 1.0);
        // (c) bit-identical fronts, baseline and counters across workers
        for w in [2usize, 8] {
            let alt = run_ga_cluster(w, None, false);
            assert_rows_equal(&base.rows, &alt.rows);
            assert_rows_equal(&base.fallback_front, &alt.fallback_front);
            assert_eq!(base.stats, alt.stats, "GA counters diverge at {w} workers");
            assert_eq!(base.evaluated, alt.evaluated);
            assert_eq!(base.enumerated, alt.enumerated);
        }
    }

    /// `--run-dir`/`--resume` cover the GA search: a second invocation
    /// against a completed journal replays the backbone from
    /// `run_journal.bin`, resumes the GA from its final `ga_journal.bin`
    /// checkpoint, re-evaluates nothing, and reproduces the front
    /// bit-identically — even at a different worker count.
    #[test]
    fn ga_cluster_resumes_bit_identically_from_a_completed_journal() {
        let dir = std::env::temp_dir()
            .join(format!("monet_ga_cluster_resume_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let a = run_ga_cluster(2, Some(dir.clone()), false);
        let b = run_ga_cluster(8, Some(dir.clone()), true);
        assert!(b.ga_resumed, "GA journal checkpoint not picked up");
        assert!(b.resumed > 0, "backbone rows not replayed from the run journal");
        assert_eq!(b.stats.evaluated, 0, "a completed run must resume with zero re-evaluations");
        assert_rows_equal(&a.rows, &b.rows);
        assert_rows_equal(&a.fallback_front, &b.fallback_front);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gpt2_strategy_ranking_flips_between_edge_and_datacenter() {
        let out = gpt2_cluster_outcome();
        let lat = |tier: LinkTier, f: (usize, usize, usize)| {
            out.rows
                .iter()
                .find(|r| r.tier == tier && r.devices == 4 && r.factorization() == f)
                .expect("enumerated factorization present")
                .latency_cycles
        };
        let (dp, tp) = ((4usize, 1usize, 1usize), (1usize, 1usize, 4usize));
        // edge fabric: per-layer collectives pay the hop latency dozens of
        // times per iteration — chatty tensor parallelism must lose to a
        // single gradient all-reduce
        assert!(
            lat(LinkTier::Edge, tp) > lat(LinkTier::Edge, dp),
            "TP must rank below DP on the edge tier"
        );
        // datacenter fabric: collectives are nearly free, and TP's ideal
        // split also divides the batch-independent weight streaming that a
        // batch-sliced DP replica keeps paying in full
        assert!(
            lat(LinkTier::Datacenter, tp) < lat(LinkTier::Datacenter, dp),
            "TP must rank above DP on the datacenter tier"
        );
        // hence the latency-optimal factorization differs across tiers
        // whenever TP tops the datacenter slice
        let best_dc = best_latency_factorization(&out.rows, LinkTier::Datacenter, 4);
        let best_edge = best_latency_factorization(&out.rows, LinkTier::Edge, 4);
        assert!(best_dc.is_some() && best_edge.is_some());
        assert_ne!(
            best_edge, best_dc,
            "edge and datacenter slices agree on the optimum — no tier sensitivity"
        );
    }
}
