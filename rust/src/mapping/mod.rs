//! Mapping configuration (DESIGN.md S6): how the scheduler exploits the
//! parallelism dimensions of §II-C1. Spatial utilization itself lives in
//! `hardware::core` (it is a property of op × dataflow); this module owns
//! the deployment-level knobs and core-selection policy.

use crate::hardware::accelerator::Accelerator;
use crate::workload::op::OpKind;

/// Deployment knobs for one scheduling run.
#[derive(Debug, Clone, Copy)]
pub struct MappingConfig {
    /// Gang size for tensor parallelism: MAC-heavy groups are split across
    /// this many identical MAC cores (output-channel split, paper §IV-A).
    pub tensor_parallel: usize,
    /// Intra-core tiling factor applied to fused subgraphs (number of
    /// output tiles streamed through local memory; bounds the working set
    /// and is the T_i of the fusion constraint in §V-A).
    pub intra_core_tiling: usize,
}

impl Default for MappingConfig {
    fn default() -> Self {
        MappingConfig { tensor_parallel: 1, intra_core_tiling: 4 }
    }
}

impl MappingConfig {
    /// The Edge-TPU mapping the paper uses for §IV-A: pipeline parallelism
    /// across heterogeneous cores + tensor parallelism distributing conv
    /// output channels over the weight-stationary PEs (the scheduler picks
    /// the best gang width up to this cap per subgraph).
    pub fn edge_tpu_default() -> Self {
        MappingConfig { tensor_parallel: 64, intra_core_tiling: 4 }
    }

    /// FuseMax (§IV-B): two big cores, pipeline parallelism only.
    pub fn fusemax_default() -> Self {
        MappingConfig { tensor_parallel: 1, intra_core_tiling: 8 }
    }
}

/// Rank candidate cores for an op class: MAC ops prefer MAC cores, the
/// rest prefer SIMD cores; ties are broken by the scheduler on earliest
/// finish time. Returns core ids in preference order.
pub fn candidate_cores(accel: &Accelerator, dominant: &OpKind) -> Vec<usize> {
    let mut ids: Vec<usize> = (0..accel.cores.len()).collect();
    ids.sort_by(|&a, &b| {
        let fa = accel.cores[a].affinity(dominant);
        let fb = accel.cores[b].affinity(dominant);
        // total order, no NaN panic (affinity() returns constants today,
        // but the ranking must survive a cost-model change that doesn't)
        fb.total_cmp(&fa)
    });
    ids
}

/// The op that decides a fused group's core affinity: the one with the
/// most MACs (a conv/GEMM if present, else the largest elementwise op).
pub fn dominant_op<'a>(kinds: impl Iterator<Item = &'a OpKind>) -> Option<&'a OpKind> {
    kinds.max_by_key(|k| (k.is_conv() || k.is_gemm(), k.macs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::EdgeTpuParams;
    use crate::workload::op::{ConvSpec, EltwiseKind};

    fn conv_kind() -> OpKind {
        OpKind::Conv(ConvSpec {
            batch: 1,
            in_ch: 16,
            out_ch: 32,
            in_h: 8,
            in_w: 8,
            k_h: 3,
            k_w: 3,
            stride: 1,
            padding: 1,
            groups: 1,
        })
    }

    #[test]
    fn conv_prefers_mac_cores() {
        let a = EdgeTpuParams::baseline().build();
        let pref = candidate_cores(&a, &conv_kind());
        assert!(a.mac_cores().contains(&pref[0]));
    }

    #[test]
    fn relu_prefers_simd_core() {
        let a = EdgeTpuParams::baseline().build();
        let relu = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 4096, arity: 1 };
        let pref = candidate_cores(&a, &relu);
        assert!(a.simd_cores().contains(&pref[0]));
    }

    #[test]
    fn ranking_comparator_tolerates_nan() {
        // regression: the descending-affinity comparator used to be
        // partial_cmp().unwrap(). affinity() returns constants today, so a
        // NaN cannot reach candidate_cores through the public API — this
        // pins the comparator pattern itself: total ordering, no panic,
        // NaN ranked ahead of nothing real in descending order.
        let mut v = [(0usize, 2.0f64), (1, f64::NAN), (2, 5.0)];
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        assert_eq!(v[0].0, 1); // NaN is total_cmp's maximum → first when descending
        assert_eq!(v[1].0, 2);
        assert_eq!(v[2].0, 0);
        // stability: equal affinities keep id order (scheduler tie-break
        // relies on a deterministic preference list)
        let a = EdgeTpuParams::baseline().build();
        let pref = candidate_cores(&a, &conv_kind());
        let macs: Vec<usize> =
            pref.iter().copied().filter(|i| a.mac_cores().contains(i)).collect();
        assert!(macs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn dominant_op_picks_mac_work() {
        let conv = conv_kind();
        let relu = OpKind::Eltwise { kind: EltwiseKind::Relu, elems: 1 << 30, arity: 1 };
        let kinds = [relu.clone(), conv.clone()];
        let d = dominant_op(kinds.iter()).unwrap();
        assert!(d.is_conv());
    }
}
