//! # MONET — Modeling and Optimization of neural NEtwork Training
//!
//! A from-scratch reproduction of the MONET framework (Morlier et al.,
//! 2026): modeling and optimization of full neural-network *training*
//! workloads (forward + backward + optimizer) on heterogeneous dataflow
//! accelerators (HDAs), with layer-fused scheduling, a constraint-based
//! fusion solver, and NSGA-II activation-checkpointing optimization.
//!
//! Architecture (see DESIGN.md and the README's module map):
//! * [`workload`] — operator-graph IR + model zoo (ResNet-18/50, GPT-2, MLP)
//! * [`autodiff`] — training-graph generation + checkpointing transform
//! * [`hardware`] — HDA model: dataflow cores, memories, interconnect,
//!   presets incl. the edge/server/datacenter device-class configurations
//! * [`mapping`] — spatial/temporal mapping + utilization
//! * [`cost`] — analytical latency/energy/memory cost model
//! * [`scheduler`] — layer-fused event-driven scheduler
//! * [`eval`] — memoized, parallel evaluation engine (group-cost cache)
//! * [`fusion`] — constraint fusion solver (BFS candidates + exact cover)
//! * [`parallelism`] — DP/PP/TP deployment arithmetic: homogeneous
//!   clusters, their 3D hybrid, and heterogeneous edge-to-datacenter
//!   clusters with stage placement ([`parallelism::hetero`])
//! * [`ga`] — NSGA-II and the checkpointing problem encoding
//! * [`dse`] — design-space exploration: the generic [`dse::engine`]
//!   evaluation harness (one worker pool + cache lifecycle behind every
//!   sweep/search/GA batch) plus the searchable spaces
//! * [`figures`] — one function per paper artifact (CSV + returned rows)
//! * [`serve`] — DSE-as-a-service: the `monet serve` HTTP/JSON daemon
//!   answering concurrent optimization queries from one resident cache
//! * [`runtime`] — PJRT client executing AOT-compiled JAX/Pallas artifacts
//! * [`report`] — CSV / ASCII figure emitters
//! * [`util`] — small self-contained infrastructure (RNG, JSON, stats)
//! * [`audit`] — `monet-audit`: static checker enforcing the standing
//!   contracts (contract-version drift, evaluator purity, determinism)
//!   at CI time

pub mod audit;
pub mod autodiff;
pub mod cost;
pub mod eval;
pub mod figures;
pub mod fusion;
pub mod dse;
pub mod ga;
pub mod hardware;
pub mod mapping;
pub mod parallelism;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod workload;

pub mod util;
