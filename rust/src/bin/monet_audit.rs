//! `monet-audit` — static contract checker for the standing contracts
//! (see `docs/AUDIT.md` and the `monet::audit` module docs).
//!
//! ```text
//! monet_audit [--check | --bless] [--root DIR] [--manifest FILE]
//!             [--github] [--prefix P] [--verbose]
//! ```
//!
//! Exit codes: 0 clean, 1 active findings (or bless refused), 2 usage /
//! IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use monet::audit::{self, default_config, fingerprint, Finding, SourceTree};

const USAGE: &str = "monet-audit: static contract checker (docs/AUDIT.md)

USAGE:
    monet_audit [--check] [OPTIONS]     verify the standing contracts (default)
    monet_audit --bless [OPTIONS]       re-pin contract fingerprints after a
                                        CACHE_CONTRACT_VERSION bump

OPTIONS:
    --root DIR        crate root holding src/ (default .)
    --manifest FILE   fingerprint manifest (default ../ci/contract_fingerprints.json)
    --github          emit GitHub Actions annotations, grouped per rule
    --prefix P        path prefix for annotations (default rust/)
    --verbose         also print waived findings with their allow reasons
    --help            this text
";

struct Opts {
    bless: bool,
    root: PathBuf,
    manifest: PathBuf,
    github: bool,
    prefix: String,
    verbose: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        bless: false,
        root: PathBuf::from("."),
        manifest: PathBuf::from("../ci/contract_fingerprints.json"),
        github: false,
        prefix: "rust/".to_string(),
        verbose: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => opts.bless = false,
            "--bless" => opts.bless = true,
            "--github" => opts.github = true,
            "--verbose" => opts.verbose = true,
            "--root" => opts.root = PathBuf::from(args.next().ok_or("--root needs a value")?),
            "--manifest" => {
                opts.manifest = PathBuf::from(args.next().ok_or("--manifest needs a value")?)
            }
            "--prefix" => opts.prefix = args.next().ok_or("--prefix needs a value")?,
            "--help" | "-h" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument '{other}'\n\n{USAGE}")),
        }
    }
    Ok(opts)
}

fn print_findings(findings: &[Finding], opts: &Opts) {
    let active: Vec<&Finding> = findings.iter().filter(|f| f.is_active()).collect();
    let waived: Vec<&Finding> = findings.iter().filter(|f| !f.is_active()).collect();

    let mut last_rule = None;
    for f in &active {
        if opts.github && last_rule != Some(f.rule) {
            if last_rule.is_some() {
                println!("::endgroup::");
            }
            println!("::group::rule {}", f.rule);
            last_rule = Some(f.rule);
        }
        println!("{f}");
        if opts.github {
            println!(
                "::error file={}{},line={},title={}::{}",
                opts.prefix,
                f.file.display(),
                f.line.max(1),
                f.rule,
                f.message.replace('\n', " ")
            );
        }
    }
    if opts.github && last_rule.is_some() {
        println!("::endgroup::");
    }
    if opts.verbose {
        for f in &waived {
            println!("{f}");
        }
    }
    if active.is_empty() {
        println!(
            "monet-audit: clean ({} waived finding(s) with documented reasons)",
            waived.len()
        );
    } else {
        println!(
            "monet-audit: {} active finding(s), {} waived",
            active.len(),
            waived.len()
        );
    }
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    let cfg = default_config();

    if opts.bless {
        let tree = match SourceTree::load(&opts.root) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("monet-audit: cannot read {}: {e}", opts.root.display());
                return ExitCode::from(2);
            }
        };
        return match fingerprint::bless(&tree, &cfg, &opts.manifest) {
            Ok(msg) => {
                println!("monet-audit: {msg}");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("monet-audit: {e}");
                ExitCode::FAILURE
            }
        };
    }

    match audit::run_audit(&opts.root, &cfg, &opts.manifest) {
        Ok(findings) => {
            print_findings(&findings, &opts);
            if findings.iter().any(|f| f.is_active()) {
                ExitCode::FAILURE
            } else {
                ExitCode::SUCCESS
            }
        }
        Err(e) => {
            eprintln!("monet-audit: IO error: {e}");
            ExitCode::from(2)
        }
    }
}
