#!/usr/bin/env python3
"""Fail CI if any BENCH_*.json dropped a previously-present key.

The bench files are the repo's performance trajectory across PRs: a key
that disappears (a family silently dropped from a bench, a renamed
field) breaks cross-PR comparability without failing any test. The
manifest ci/bench_keys.json lists, per bench file, every dotted key
path that must stay present. Emitting MORE keys is always fine — add
them to the manifest in the same PR that introduces them, which makes
them load-bearing for every PR after.

Usage: check_bench_keys.py <dir-holding-BENCH-files>
"""

import json
import pathlib
import sys


def key_paths(value, prefix=""):
    """Every dotted path to a key anywhere in a nested JSON object."""
    paths = set()
    if isinstance(value, dict):
        for k, v in value.items():
            path = f"{prefix}.{k}" if prefix else k
            paths.add(path)
            paths |= key_paths(v, path)
    return paths


def main():
    bench_dir = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else pathlib.Path(".")
    manifest_path = pathlib.Path(__file__).with_name("bench_keys.json")
    manifest = json.loads(manifest_path.read_text())
    failures = []
    for fname, required in sorted(manifest.items()):
        fpath = bench_dir / fname
        if not fpath.exists():
            failures.append(f"{fname}: file missing (bench not run?)")
            continue
        present = key_paths(json.loads(fpath.read_text()))
        missing = sorted(set(required) - present)
        failures.extend(f"{fname}: key '{key}' dropped" for key in missing)
        print(
            f"{fname}: {len(required)} required keys, "
            f"{len(present)} present, {len(missing)} missing"
        )
    if failures:
        print(
            "\nbench trajectory regression — previously-present keys dropped:",
            file=sys.stderr,
        )
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("bench key trajectory OK")


if __name__ == "__main__":
    main()
