//! Figure 3: ResNet-50 peak-memory breakdown under Adam at 224², batch 1
//! vs 8 — parameters / gradients / optimizer states / activations.
//!
//! Run: `cargo run --release --example memory_breakdown`

use monet::figures::fig3_memory_breakdown;
use monet::report::{ascii_bars, fmt_bytes};
use std::path::Path;

fn main() {
    let bd = fig3_memory_breakdown(Some(Path::new("results")));
    for m in &bd {
        println!(
            "{}",
            ascii_bars(
                &format!("Fig 3: ResNet-50 Adam 224², batch {}", m.batch),
                &[
                    "parameters".into(),
                    "gradients".into(),
                    "optimizer states".into(),
                    "activations".into(),
                ],
                &[
                    m.params_bytes as f64,
                    m.grads_bytes as f64,
                    m.optstate_bytes as f64,
                    m.activation_bytes as f64,
                ],
                44
            )
        );
        println!(
            "  total {}  (activations are {:.0}% of peak)",
            fmt_bytes(m.total()),
            m.activation_bytes as f64 / m.total() as f64 * 100.0
        );
        println!();
    }
    let (b1, b8) = (&bd[0], &bd[1]);
    println!(
        "batch 1→8: activations ×{:.1}, params+states ×1.0 — the training-memory wall the paper motivates",
        b8.activation_bytes as f64 / b1.activation_bytes as f64
    );
    println!("CSV written to results/fig3_memory_breakdown.csv");
}
