//! Figure 9: GPT-2 training vs inference over the FuseMax space
//! (Table III), colour-coded by global-buffer bandwidth.
//!
//! Run: `cargo run --release --example fusemax_gpt2 -- [stride]`

use monet::figures::{fig9_fusemax_sweep, split_modes};
use monet::report::ascii_scatter;
use monet::util::stats;
use std::path::Path;

fn main() {
    let stride: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    eprintln!("sweeping Table III with stride {stride}...");
    let sweep = fig9_fusemax_sweep(stride, Some(Path::new("results")), |d, n| {
        if d % 100 == 0 || d == n {
            eprint!("\r  {d}/{n}");
        }
    });
    eprintln!();
    let (inf, tr) = split_modes(&sweep.rows);

    for (mode, rows) in [("inference", &inf), ("training", &tr)] {
        let xs: Vec<f64> = rows.iter().map(|r| r.latency_cycles).collect();
        let ys: Vec<f64> = rows.iter().map(|r| r.energy_pj).collect();
        let marks: Vec<char> = rows
            .iter()
            .map(|r| if r.color_axis > 8192.0 { '@' } else { 'o' })
            .collect();
        println!(
            "{}",
            ascii_scatter(
                &format!("Fig 9 [{mode}]: energy vs latency; @ = 16K buffer BW, o = 8K"),
                &xs, &ys, &marks, 72, 16, true
            )
        );
        // the paper's observation: distributions are more concentrated than
        // the Edge-TPU case (regular workload × regular hardware)
        let lat: Vec<f64> = rows.iter().map(|r| r.latency_cycles.log10()).collect();
        println!(
            "  log10-latency spread: stddev {:.3} over [{:.2}, {:.2}]\n",
            stats::stddev(&lat),
            lat.iter().cloned().fold(f64::MAX, f64::min),
            lat.iter().cloned().fold(f64::MIN, f64::max),
        );
    }
    println!("CSV written to results/fig9_fusemax_sweep.csv");
}
