//! End-to-end stack validation (DESIGN.md E2E): train the tiny GPT-2 whose
//! training step was AOT-compiled from JAX (+ the Pallas flash-attention
//! kernel) through PJRT, driven entirely from rust — then compare the
//! *measured* per-step wallclock against MONET's *modeled* cycle count for
//! the same workload on the FuseMax HDA, the model-vs-measured discipline
//! Stream inherits.
//!
//! Run: `cargo run --release --example e2e_train -- [steps]`
//! (requires `make artifacts` first)

use monet::autodiff::{build_training_graph, TrainOptions};
use monet::hardware::presets::FuseMaxParams;
use monet::mapping::MappingConfig;
use monet::report::write_csv;
use monet::runtime::{Corpus, Gpt2Runner, Runtime};
use monet::scheduler::{schedule, Partition};
use monet::workload::models::{gpt2, Gpt2Config};
use monet::workload::op::Optimizer;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    // ---- real execution through the AOT artifacts ----
    let rt = Runtime::new("artifacts")?;
    let mut runner = Gpt2Runner::load(&rt, "tiny")?;
    let meta = runner.meta.clone();
    println!(
        "tiny GPT-2 ({} params) on PJRT [{}]; {} steps on a synthetic byte corpus",
        meta.num_params,
        rt.platform(),
        steps
    );
    let mut corpus = Corpus::synthetic(meta.vocab, 64 * 1024, 42);
    let mut losses: Vec<f64> = vec![];
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let tokens = corpus.next_batch(meta.batch, meta.seq + 1);
        let loss = runner.step(&tokens)? as f64;
        losses.push(loss);
        if step % 25 == 0 || step == 1 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    let wall = t0.elapsed();
    let ms_per_step = wall.as_secs_f64() * 1e3 / steps as f64;
    println!(
        "\nloss {:.3} → {:.3} over {steps} steps ({:.1} ms/step measured)",
        losses[0],
        losses[losses.len() - 1],
        ms_per_step
    );
    assert!(
        losses[losses.len() - 1] < 0.7 * losses[0],
        "training failed to reduce loss — stack broken"
    );
    write_csv(
        "results/e2e_train_loss.csv",
        "step,loss",
        losses.iter().enumerate().map(|(i, l)| vec![(i + 1).to_string(), format!("{l:.5}")]),
    )?;

    // ---- MONET's model of the same workload ----
    let cfg = Gpt2Config {
        vocab: meta.vocab,
        seq: meta.seq,
        d_model: meta.d_model,
        n_head: 4,
        n_layer: meta.n_layer,
        mlp_ratio: 4,
        batch: meta.batch,
    };
    let fwd = gpt2(cfg);
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    let accel = FuseMaxParams::baseline().build();
    let r = schedule(
        &tg.graph,
        &Partition::singletons(&tg.graph),
        &accel,
        &MappingConfig::fusemax_default(),
    );
    let modeled_ms = r.latency_cycles / (accel.clock_ghz * 1e9) * 1e3;
    println!(
        "\nmodel-vs-measured: MONET predicts {:.3} ms/step on FuseMax@{}GHz ({:.3e} cycles);",
        modeled_ms, accel.clock_ghz, r.latency_cycles
    );
    println!(
        "measured {:.1} ms/step on this CPU — a {:.0}× gap consistent with a {}-lane dataflow
accelerator vs one interpreted-Pallas CPU core (absolute-scale sanity, not calibration).",
        ms_per_step,
        ms_per_step / modeled_ms,
        accel.total_macs()
    );
    println!("loss curve written to results/e2e_train_loss.csv");
    Ok(())
}
