//! Memory-reduction techniques from the paper's §II-A, combined and
//! compared on one workload: Adam vs GaLore optimizer states, Gist-style
//! compressed activations, and GA-driven activation checkpointing — the
//! whole training-memory toolbox MONET can reason about.
//!
//! Run: `cargo run --release --example memory_techniques`

use monet::autodiff::{build_training_graph, stored_activation_bytes, CheckpointPlan, TrainOptions};
use monet::fusion::FusionConstraints;
use monet::ga::{CheckpointProblem, GaConfig};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::report::{ascii_bars, fmt_bytes, write_csv};
use monet::workload::models::{mobilenet_v2, resnet18};
use monet::workload::op::Optimizer;

fn main() {
    let accel = EdgeTpuParams::baseline().build();
    let mut csv = vec![];

    for (name, fwd) in [
        ("resnet18/224", resnet18(1, 224, 1000)),
        ("mobilenet_v2/224", mobilenet_v2(1, 224, 1000, 100)),
    ] {
        println!("=== {name} (batch 1) ===\n");
        let adam = build_training_graph(
            &fwd,
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        );
        let galore = build_training_graph(
            &fwd,
            TrainOptions { optimizer: Optimizer::Galore, include_update: true },
        );

        // checkpointing: best ≤5%-latency plan from a quick GA
        let problem = CheckpointProblem::new(
            &adam,
            &accel,
            MappingConfig::edge_tpu_default(),
            FusionConstraints::default(),
        );
        let (base_lat, _, _) = problem.evaluate(&CheckpointPlan::save_all());
        let front = problem.optimize(&GaConfig {
            population: 16,
            generations: 10,
            ..Default::default()
        });
        let ckpt_plan = front
            .iter()
            .filter(|s| s.latency_cycles <= base_lat * 1.05)
            .max_by(|a, b| a.memory_saving.partial_cmp(&b.memory_saving).unwrap())
            .map(|s| s.plan.clone())
            .unwrap_or_default();

        let params = adam.param_bytes();
        let grads = adam.grad_bytes();
        let acts = adam.saved_activation_bytes();
        let rows: Vec<(&str, u64)> = vec![
            ("baseline (Adam, raw acts)", params + grads + adam.optimizer_state_bytes() + acts),
            ("+ GaLore states", params + grads + galore.optimizer_state_bytes() + acts),
            ("+ Gist activations", params + grads + galore.optimizer_state_bytes() + adam.saved_activation_bytes_gist()),
            (
                "+ GA checkpointing (≤5% lat)",
                params
                    + grads
                    + galore.optimizer_state_bytes()
                    + stored_activation_bytes(&adam, &ckpt_plan).min(adam.saved_activation_bytes_gist()),
            ),
        ];
        let labels: Vec<String> = rows.iter().map(|(l, _)| l.to_string()).collect();
        let vals: Vec<f64> = rows.iter().map(|(_, v)| *v as f64).collect();
        println!("{}", ascii_bars("training-iteration memory footprint", &labels, &vals, 40));
        for (l, v) in &rows {
            println!("  {l:<30} {}", fmt_bytes(*v));
            csv.push(vec![name.to_string(), l.to_string(), v.to_string()]);
        }
        let total0 = rows[0].1 as f64;
        let totaln = rows[rows.len() - 1].1 as f64;
        println!(
            "\n  stacked techniques: {:.1}% of baseline memory\n",
            totaln / total0 * 100.0
        );
    }
    write_csv("results/memory_techniques.csv", "workload,configuration,bytes", csv).unwrap();
    println!("CSV: results/memory_techniques.csv");
}
