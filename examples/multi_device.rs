//! Parallelism strategies across multiple HDAs (paper §II-C1, Fig 5 made
//! quantitative): data / pipeline / tensor parallelism for ResNet-18
//! training on clusters of baseline Edge TPUs.
//!
//! Run: `cargo run --release --example multi_device`

use monet::autodiff::{build_training_graph, TrainOptions};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{model_strategy, Cluster, Strategy};
use monet::report::{fmt_bytes, write_csv};
use monet::workload::models::resnet18;
use monet::workload::op::Optimizer;

fn main() {
    let accel = EdgeTpuParams::baseline().build();
    let mapping = MappingConfig::edge_tpu_default();
    let builder = |batch: usize| {
        build_training_graph(
            &resnet18(batch.max(1), 32, 10),
            TrainOptions { optimizer: Optimizer::Adam, include_update: true },
        )
    };
    let full_batch = 16;

    println!("ResNet-18 training (Adam, batch {full_batch}) on clusters of baseline Edge TPUs");
    println!(
        "{:<26} {:>4} {:>14} {:>13} {:>12} {:>12}",
        "strategy", "n", "latency (cyc)", "energy (pJ)", "mem/device", "comm"
    );
    let mut csv_rows = vec![];
    for n in [1usize, 2, 4, 8] {
        let cluster =
            Cluster { devices: n, link_bw: 64.0, link_energy_pj: 10.0, hop_cycles: 0.0 };
        for (name, s) in [
            ("data-parallel", Strategy::DataParallel),
            ("pipeline (m=8)", Strategy::Pipeline { microbatches: 8 }),
            ("tensor-parallel", Strategy::TensorParallel),
            (
                "hybrid (dp2,pp=n/2,m=8)",
                Strategy::Hybrid {
                    dp: 2.min(n),
                    pp_stages: (n / 2).max(1),
                    microbatches: 8,
                    tp: 1,
                },
            ),
        ] {
            let r = model_strategy(s, full_batch, &builder, &accel, &mapping, &cluster);
            println!(
                "{:<26} {:>4} {:>14.3e} {:>13.3e} {:>12} {:>12}",
                name,
                n,
                r.latency_cycles,
                r.energy_pj,
                fmt_bytes(r.per_device_mem_bytes),
                fmt_bytes(r.comm_bytes as u64),
            );
            csv_rows.push(vec![
                name.to_string(),
                n.to_string(),
                format!("{:.6e}", r.latency_cycles),
                format!("{:.6e}", r.energy_pj),
                r.per_device_mem_bytes.to_string(),
                format!("{:.3e}", r.comm_bytes),
            ]);
        }
        println!();
    }
    write_csv(
        "results/multi_device.csv",
        "strategy,devices,latency_cycles,energy_pj,per_device_mem_bytes,comm_bytes",
        csv_rows,
    )
    .unwrap();
    println!(
        "Takeaways (paper §II-C1): data parallelism buys latency but replicates all\n\
         optimizer state per device; pipelining cuts per-device memory at fill/drain\n\
         cost; tensor parallelism shards state but pays per-layer reduction traffic.\n\
         CSV: results/multi_device.csv"
    );
}
