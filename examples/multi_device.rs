//! Parallelism strategies across multiple HDAs (paper §II-C1, Fig 5 made
//! quantitative) — and the canonical **"add your own design space"**
//! example for the generic `dse::engine` harness: define a point type, a
//! `DesignSpace` (deterministic enumeration + stable ids) and an
//! `Evaluate` instance, and `Engine::run` supplies the worker pool, the
//! shared cost-cache lifecycle, progress reporting and deterministic row
//! ordering — no hand-rolled threading.
//!
//! Run: `cargo run --release --example multi_device`

use monet::autodiff::{build_training_graph, TrainOptions, TrainingGraph};
use monet::dse::{ClusterScratch, DesignSpace, Engine, EngineConfig, Evaluate};
use monet::eval::CostCache;
use monet::hardware::accelerator::Accelerator;
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::parallelism::{model_strategy_memo, Cluster, Strategy};
use monet::report::{fmt_bytes, write_csv};
use monet::workload::models::resnet18;
use monet::workload::op::Optimizer;

/// 1. Your point type: one (strategy, cluster size) cell of the grid.
struct StrategyPoint {
    name: &'static str,
    strategy: Strategy,
    devices: usize,
}

/// 2. Your `DesignSpace`: deterministic enumeration + stable ids.
struct StrategyGrid {
    points: Vec<StrategyPoint>,
}

impl StrategyGrid {
    fn paper_grid() -> Self {
        let mut points = vec![];
        for n in [1usize, 2, 4, 8] {
            points.push(StrategyPoint {
                name: "data-parallel",
                strategy: Strategy::DataParallel,
                devices: n,
            });
            points.push(StrategyPoint {
                name: "pipeline (m=8)",
                strategy: Strategy::Pipeline { microbatches: 8 },
                devices: n,
            });
            points.push(StrategyPoint {
                name: "tensor-parallel",
                strategy: Strategy::TensorParallel,
                devices: n,
            });
            points.push(StrategyPoint {
                name: "hybrid (dp2,pp=n/2,m=8)",
                strategy: Strategy::Hybrid {
                    dp: 2.min(n),
                    pp_stages: (n / 2).max(1),
                    microbatches: 8,
                    tp: 1,
                },
                devices: n,
            });
        }
        StrategyGrid { points }
    }
}

impl DesignSpace for StrategyGrid {
    type Point = StrategyPoint;

    fn points(&self) -> &[StrategyPoint] {
        &self.points
    }

    fn point_id(&self, index: usize) -> String {
        let p = &self.points[index];
        format!("{},n{}", p.name, p.devices)
    }
}

/// The training-graph builder — must be a pure function of the batch
/// (the per-worker scratch memoizes it).
fn resnet18_builder(batch: usize) -> TrainingGraph {
    build_training_graph(
        &resnet18(batch.max(1), 32, 10),
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    )
}

/// One result row (your own shape — the engine is generic over it).
struct Row {
    name: &'static str,
    devices: usize,
    latency_cycles: f64,
    energy_pj: f64,
    per_device_mem_bytes: u64,
    comm_bytes: f64,
}

/// 3. Your `Evaluate` instance. The contract: a pure function of
/// (index, point, &self); the scratch may only memoize pure work (here:
/// the per-batch training graphs and the balanced stage cuts, via the
/// reusable `ClusterScratch`).
struct StrategyEval {
    accel: Accelerator,
    mapping: MappingConfig,
    full_batch: usize,
}

impl Evaluate for StrategyEval {
    type Point = StrategyPoint;
    type Row = Row;
    type Scratch = ClusterScratch;

    fn scratch(&self) -> ClusterScratch {
        ClusterScratch::default()
    }

    fn evaluate(
        &self,
        _index: usize,
        p: &StrategyPoint,
        cache: Option<&CostCache>,
        scratch: &mut ClusterScratch,
    ) -> Vec<Row> {
        let builder = scratch.graph_builder(&resnet18_builder);
        let cluster = Cluster {
            devices: p.devices,
            link_bw: 64.0,
            link_energy_pj: 10.0,
            hop_cycles: 0.0,
        };
        let r = model_strategy_memo(
            p.strategy,
            self.full_batch,
            &builder,
            &self.accel,
            &self.mapping,
            &cluster,
            cache,
            Some(&scratch.cuts),
        );
        vec![Row {
            name: p.name,
            devices: p.devices,
            latency_cycles: r.latency_cycles,
            energy_pj: r.energy_pj,
            per_device_mem_bytes: r.per_device_mem_bytes,
            comm_bytes: r.comm_bytes,
        }]
    }
}

fn main() {
    let full_batch = 16;
    let space = StrategyGrid::paper_grid();
    let eval = StrategyEval {
        accel: EdgeTpuParams::baseline().build(),
        mapping: MappingConfig::edge_tpu_default(),
        full_batch,
    };

    // 4. One call: worker pool, shared cost cache, deterministic order.
    let (rows, stats) = Engine::new(EngineConfig::default()).run(&space, &eval, |_, _| {});

    println!("ResNet-18 training (Adam, batch {full_batch}) on clusters of baseline Edge TPUs");
    println!(
        "{:<26} {:>4} {:>14} {:>13} {:>12} {:>12}",
        "strategy", "n", "latency (cyc)", "energy (pJ)", "mem/device", "comm"
    );
    let mut csv_rows = vec![];
    let mut last_devices = 0usize;
    for r in &rows {
        if last_devices != 0 && r.devices != last_devices {
            println!();
        }
        last_devices = r.devices;
        println!(
            "{:<26} {:>4} {:>14.3e} {:>13.3e} {:>12} {:>12}",
            r.name,
            r.devices,
            r.latency_cycles,
            r.energy_pj,
            fmt_bytes(r.per_device_mem_bytes),
            fmt_bytes(r.comm_bytes as u64),
        );
        csv_rows.push(vec![
            r.name.to_string(),
            r.devices.to_string(),
            format!("{:.6e}", r.latency_cycles),
            format!("{:.6e}", r.energy_pj),
            r.per_device_mem_bytes.to_string(),
            format!("{:.3e}", r.comm_bytes),
        ]);
    }
    write_csv(
        "results/multi_device.csv",
        "strategy,devices,latency_cycles,energy_pj,per_device_mem_bytes,comm_bytes",
        csv_rows,
    )
    .unwrap();
    println!(
        "\nShared group-cost cache across the pool: {} hits / {} misses ({:.1}% hit rate)",
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0
    );
    println!(
        "Takeaways (paper §II-C1): data parallelism buys latency but replicates all\n\
         optimizer state per device; pipelining cuts per-device memory at fill/drain\n\
         cost; tensor parallelism shards state but pays per-layer reduction traffic.\n\
         To add your own design space: a point type + DesignSpace + Evaluate, then\n\
         Engine::run — the worker pool, cache lifecycle and determinism come free.\n\
         CSV: results/multi_device.csv"
    );
}
