//! Figures 1 & 8: design-space exploration of ResNet-18 training vs
//! inference over the Edge-TPU space (Table II).
//!
//! Run: `cargo run --release --example edge_dse -- [stride]`
//! (stride 1 = the full 10 000-point space, ~2 min on one core)

use monet::dse::pareto_front;
use monet::figures::{fig1_fig8_edge_sweep, split_modes};
use monet::report::ascii_scatter;
use std::path::Path;

fn main() {
    let stride: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    eprintln!("sweeping Table II with stride {stride}...");
    let sweep = fig1_fig8_edge_sweep(stride, Some(Path::new("results")), |d, n| {
        if d % 200 == 0 || d == n {
            eprint!("\r  {d}/{n}");
        }
    });
    eprintln!();
    let (inf, tr) = split_modes(&sweep.rows);

    // Fig 1: energy vs latency, per mode
    for (mode, rows) in [("inference", &inf), ("training", &tr)] {
        let xs: Vec<f64> = rows.iter().map(|r| r.latency_cycles).collect();
        let ys: Vec<f64> = rows.iter().map(|r| r.energy_pj).collect();
        let cmax = rows.iter().map(|r| r.color_axis).fold(f64::MIN, f64::max);
        let marks: Vec<char> = rows
            .iter()
            .map(|r| ['.', ':', 'o', 'O', '@'][(r.color_axis / cmax * 4.0).min(4.0) as usize])
            .collect();
        println!(
            "{}",
            ascii_scatter(
                &format!("Fig 1 [{mode}]: energy (pJ) vs latency (cycles); mark = U·L"),
                &xs, &ys, &marks, 72, 16, true
            )
        );
    }

    // Fig 8 views: latency & energy vs total compute resource
    for (mode, rows) in [("inference", &inf), ("training", &tr)] {
        for (metric, get) in [
            ("latency", (|r: &monet::dse::SweepRow| r.latency_cycles) as fn(&monet::dse::SweepRow) -> f64),
            ("energy", |r: &monet::dse::SweepRow| r.energy_pj),
        ] {
            let xs: Vec<f64> = rows.iter().map(|r| r.total_macs as f64).collect();
            let ys: Vec<f64> = rows.iter().map(get).collect();
            let cmax = rows.iter().map(|r| r.color_axis).fold(f64::MIN, f64::max);
            let marks: Vec<char> = rows
                .iter()
                .map(|r| ['.', ':', 'o', 'O', '@'][(r.color_axis / cmax * 4.0).min(4.0) as usize])
                .collect();
            println!(
                "{}",
                ascii_scatter(
                    &format!("Fig 8 [{mode}]: {metric} vs total compute resource U·L·nPE"),
                    &xs, &ys, &marks, 72, 14, true
                )
            );
        }
    }

    // the paper's headline: Pareto sets differ between modes, and large
    // PEs behave differently for training vs inference latency
    let pi = pareto_front(&inf);
    let pt = pareto_front(&tr);
    let avg_pe = |rows: &[monet::dse::SweepRow], f: &[usize]| -> f64 {
        f.iter().map(|&i| rows[i].color_axis).sum::<f64>() / f.len().max(1) as f64
    };
    println!("latency-energy Pareto: inference {} configs (avg U·L {:.0}), training {} configs (avg U·L {:.0})",
        pi.len(), avg_pe(&inf, &pi), pt.len(), avg_pe(&tr, &pt));
    let pi_set: std::collections::HashSet<&str> =
        pi.iter().map(|&i| inf[i].label.as_str()).collect();
    let pt_set: std::collections::HashSet<&str> =
        pt.iter().map(|&i| tr[i].label.as_str()).collect();
    let shared = pi_set.intersection(&pt_set).count();
    println!(
        "Pareto overlap: {shared} shared of {}/{} — architectures optimal for one mode are not optimal for the other",
        pi_set.len(),
        pt_set.len()
    );
    println!("CSV written to results/fig1_fig8_edge_sweep.csv");
}
