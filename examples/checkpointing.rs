//! Figures 11 & 12: activation checkpointing — the non-linearity
//! demonstration (AC10/AC01/AC11 deltas) and the NSGA-II Pareto front.
//!
//! Run: `cargo run --release --example checkpointing -- [linearity|ga|both] [pop] [gens]`

use monet::figures::{fig11_checkpoint_linearity, fig12_checkpoint_ga, linearity_gap};
use monet::ga::GaConfig;
use monet::report::{ascii_bars, ascii_scatter};
use std::path::Path;

fn run_linearity() {
    let rows = fig11_checkpoint_linearity(Some(Path::new("results")));
    let labels: Vec<String> = rows.iter().map(|r| r.scenario.clone()).collect();
    println!(
        "{}",
        ascii_bars(
            "Fig 11: Δ latency vs save-all (cycles)",
            &labels,
            &rows.iter().map(|r| r.latency_delta).collect::<Vec<_>>(),
            36
        )
    );
    println!(
        "{}",
        ascii_bars(
            "Fig 11: Δ energy vs save-all (pJ)",
            &labels,
            &rows.iter().map(|r| r.energy_delta).collect::<Vec<_>>(),
            36
        )
    );
    let (gl, ge) = linearity_gap(&rows);
    println!(
        "Δ(AC11) − Δ(AC10) − Δ(AC01): latency gap {:.1}%, energy gap {:.1}%",
        gl * 100.0,
        ge * 100.0
    );
    println!("→ a linear (MILP) cost model cannot represent fused-layer checkpointing (paper §V-B1)\n");
}

fn run_ga(pop: usize, gens: usize) {
    eprintln!("NSGA-II (pop {pop}, gens {gens}) on ResNet-18/224 training + Adam...");
    let ga = GaConfig { population: pop, generations: gens, ..Default::default() };
    let (rows, _) = fig12_checkpoint_ga(&ga, Some(Path::new("results")));
    println!("Fig 12: Pareto front — memory saving vs latency/energy overhead");
    println!("{:>10} {:>15} {:>11} {:>11}", "mem saved", "stored (MiB,16)", "Δ latency", "Δ energy");
    for r in &rows {
        println!(
            "{:>9.1}% {:>15.1} {:>10.2}% {:>10.2}%",
            r.memory_saving * 100.0,
            r.stored_mb_fp16,
            r.latency_overhead * 100.0,
            r.energy_overhead * 100.0
        );
    }
    let xs: Vec<f64> = rows.iter().map(|r| r.memory_saving * 100.0).collect();
    let ys: Vec<f64> = rows.iter().map(|r| r.latency_overhead * 100.0).collect();
    let marks = vec!['o'; rows.len()];
    println!(
        "{}",
        ascii_scatter("Fig 12: latency overhead (%) vs memory saving (%)", &xs, &ys, &marks, 64, 14, false)
    );
    // the paper's headline: ~13 MB saved for ~4% latency/energy
    if let Some(best) = rows
        .iter()
        .filter(|r| r.latency_overhead < 0.05 && r.energy_overhead < 0.05)
        .max_by(|a, b| a.memory_saving.partial_cmp(&b.memory_saving).unwrap())
    {
        let base = rows.iter().map(|r| r.stored_mb_fp16).fold(f64::MIN, f64::max);
        println!(
            "≤5% overhead buys {:.1} MiB of activation memory ({:.0}% saving, {:.1} → {:.1} MiB)",
            base - best.stored_mb_fp16,
            best.memory_saving * 100.0,
            base,
            best.stored_mb_fp16
        );
    }
    println!("CSV written to results/fig12_checkpoint_ga.csv");
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "both".into());
    let pop: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    let gens: usize = std::env::args().nth(3).and_then(|s| s.parse().ok()).unwrap_or(30);
    match mode.as_str() {
        "linearity" => run_linearity(),
        "ga" => run_ga(pop, gens),
        _ => {
            run_linearity();
            run_ga(pop, gens);
        }
    }
}
