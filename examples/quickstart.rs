//! Quickstart: model one training iteration of ResNet-18 on the baseline
//! Edge TPU, end to end through the public API — build the forward graph,
//! differentiate it, fuse it, schedule it, read the metrics.
//!
//! Run: `cargo run --release --example quickstart`

use monet::autodiff::{build_training_graph, TrainOptions};
use monet::fusion::{fuse, FusionConstraints};
use monet::hardware::presets::EdgeTpuParams;
use monet::mapping::MappingConfig;
use monet::report::fmt_bytes;
use monet::scheduler::{schedule, Partition};
use monet::workload::models::resnet18;
use monet::workload::op::Optimizer;

fn main() {
    // 1. the workload: ResNet-18 on CIFAR-sized inputs (paper §IV-A)
    let fwd = resnet18(1, 32, 10);
    println!("forward graph:  {}", fwd.summary());

    // 2. MONET's training transform: fwd + decomposed bwd + optimizer
    let tg = build_training_graph(
        &fwd,
        TrainOptions { optimizer: Optimizer::Adam, include_update: true },
    );
    println!("training graph: {}", tg.graph.summary());
    println!(
        "memory: params {}, grads {}, opt-states {}, saved activations {}",
        fmt_bytes(tg.param_bytes()),
        fmt_bytes(tg.grad_bytes()),
        fmt_bytes(tg.optimizer_state_bytes()),
        fmt_bytes(tg.saved_activation_bytes()),
    );

    // 3. the hardware: baseline Edge TPU from Table II
    let accel = EdgeTpuParams::baseline().build();
    println!("\naccelerator: {} ({} cores, {} MAC/cyc)", accel.name, accel.cores.len(), accel.total_macs());

    // 4. deployment: fused-layer partition from the §V-A solver
    let mapping = MappingConfig::edge_tpu_default();
    let fused = fuse(&tg.graph, &FusionConstraints::default());
    println!("fusion: {} nodes → {} fused subgraphs", tg.graph.len(), fused.len());

    // 5. schedule both modes, fused vs layer-by-layer
    let fused_fwd = fuse(&fwd, &FusionConstraints::default());
    println!("\n{:<28} {:>14} {:>14} {:>8}", "schedule", "latency (cyc)", "energy (pJ)", "util");
    for (name, g, p) in [
        ("inference / layer-by-layer", &fwd, Partition::singletons(&fwd)),
        ("inference / fused", &fwd, fused_fwd),
        ("training  / layer-by-layer", &tg.graph, Partition::singletons(&tg.graph)),
        ("training  / fused", &tg.graph, fused),
    ] {
        let r = schedule(g, &p, &accel, &mapping);
        println!(
            "{:<28} {:>14.3e} {:>14.3e} {:>7.1}%",
            name,
            r.latency_cycles,
            r.energy_pj,
            r.utilization() * 100.0
        );
    }
    println!(
        "\nNote the asymmetry: fusion improves both metrics for inference, but on the\n\
         training graph it trades latency for energy — the paper's core observation\n\
         that inference-tuned deployments do not transfer to training (Fig 1)."
    );

    // 6. training-phase breakdown (a view inference-only tools can't give)
    let fused2 = fuse(&tg.graph, &FusionConstraints::default());
    let r = schedule(&tg.graph, &fused2, &accel, &mapping);
    let total: f64 = r.phase_busy.iter().sum();
    println!(
        "\nphase breakdown (busy time): forward {:.0}%, backward {:.0}%, optimizer {:.0}%",
        r.phase_busy[0] / total * 100.0,
        r.phase_busy[1] / total * 100.0,
        r.phase_busy[2] / total * 100.0,
    );
}
