//! Figure 10: layer-fusion strategies on ResNet-18 inference / Edge TPU —
//! Base (layer-by-layer), Manual (conv+bn+relu), and the §V-A constraint
//! solver at subgraph limits 4..8.
//!
//! Run: `cargo run --release --example fusion_opt`

use monet::figures::fig10_fusion_strategies;
use monet::report::ascii_bars;
use std::path::Path;

fn main() {
    let rows = fig10_fusion_strategies(Some(Path::new("results")));
    let labels: Vec<String> =
        rows.iter().map(|r| format!("{} [{} groups]", r.strategy, r.n_groups)).collect();
    println!(
        "{}",
        ascii_bars(
            "Fig 10: ResNet-18 inference latency (cycles)",
            &labels,
            &rows.iter().map(|r| r.latency_cycles).collect::<Vec<_>>(),
            44
        )
    );
    println!(
        "{}",
        ascii_bars(
            "Fig 10: ResNet-18 inference energy (pJ)",
            &labels,
            &rows.iter().map(|r| r.energy_pj).collect::<Vec<_>>(),
            44
        )
    );
    let base = rows.iter().find(|r| r.strategy == "Base").unwrap();
    let manual = rows.iter().find(|r| r.strategy == "Manual").unwrap();
    let best = rows
        .iter()
        .filter(|r| r.strategy.starts_with("Limit"))
        .min_by(|a, b| a.latency_cycles.partial_cmp(&b.latency_cycles).unwrap())
        .unwrap();
    println!(
        "best solver config: {} — {:.1}% faster / {:.1}% less energy than Base; {:.1}% / {:.1}% vs Manual",
        best.strategy,
        (1.0 - best.latency_cycles / base.latency_cycles) * 100.0,
        (1.0 - best.energy_pj / base.energy_pj) * 100.0,
        (1.0 - best.latency_cycles / manual.latency_cycles) * 100.0,
        (1.0 - best.energy_pj / manual.energy_pj) * 100.0,
    );
    println!("CSV written to results/fig10_fusion_strategies.csv");
}
